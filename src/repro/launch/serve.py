"""Serving launcher: batched trajectory generation via the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch delphi-2m \
        [--requests 16] [--slots 8] [--ckpt runs/delphi] [--replicas 2]

``--replicas N`` shards the request set across N engines through the same
:class:`repro.serve.PrefixAffinityScheduler` the HTTP router uses — shared
history prefixes land on the engine whose pool already holds them, and the
engines run their ticks on concurrent background threads (jitted compute
releases the GIL, so CPU replicas genuinely overlap).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import SimulatorConfig, generate_dataset
from repro.data import vocab as V
from repro.models import init_params
from repro.serve import BatchedEngine, Request
from repro.train import restore


class _EngineShard:
    """Just enough of ``ReplicaHandle``'s surface (``name`` / ``inflight``
    / ``free_blocks``) for the affinity scheduler to rank local engines."""

    def __init__(self, name: str, engine: BatchedEngine):
        self.name = name
        self.engine = engine
        self.requests: list = []

    @property
    def inflight(self) -> int:
        return len(self.requests)

    def free_blocks(self):
        st = self.engine.pool_stats()
        return st.get("blocks_free")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="delphi-2m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="shard requests across N engines via the router's "
                         "prefix-affinity scheduler")
    ap.add_argument("--cache", choices=("ring", "paged"), default="ring",
                    help="KV layout per engine (paged enables chunked "
                         "prefill and prefix sharing)")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=None,
                    metavar="N",
                    help="--cache paged: prefill in N-token chunks "
                         "interleaved with decode ticks (multiple of the "
                         "16-token block size)")
    args = ap.parse_args()
    if args.prefill_chunk_tokens is not None and args.cache != "paged":
        ap.error("--prefill-chunk-tokens requires --cache paged")

    cfg = get_config(args.arch)
    if jax.default_backend() == "cpu":
        cfg = cfg.replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.ckpt:
        params = restore(args.ckpt, params)

    n_rep = max(args.replicas, 1)
    shards = [_EngineShard(f"r{i}", BatchedEngine(
        params, cfg, slots=args.slots, max_context=cfg.max_seq_len,
        seed=args.seed + i, cache=args.cache,
        prefill_chunk_tokens=args.prefill_chunk_tokens))
        for i in range(n_rep)]

    # prompts: prefixes of fresh synthetic patients (their known history)
    trajs, _ = generate_dataset(SimulatorConfig(
        n_train=args.requests, n_val=1, seed=args.seed + 17))
    if n_rep == 1:
        for tok, age in trajs:
            half = max(len(tok) // 2, 1)
            shards[0].requests.append(Request(
                tokens=tok[:half], ages=age[:half], max_new=args.max_new))
    else:
        from repro.serve import PrefixAffinityScheduler
        sched = PrefixAffinityScheduler(block_size=16)
        for tok, age in trajs:
            half = max(len(tok) // 2, 1)
            req = Request(tokens=tok[:half], ages=age[:half],
                          max_new=args.max_new)
            shard, _ = sched.route(req.tokens, req.ages, shards)
            shard.requests.append(req)
        st = sched.stats()
        counts = ", ".join(f"{s.name}={len(s.requests)}" for s in shards)
        print(f"sharded {args.requests} requests over {n_rep} engines "
              f"({counts}; affinity rate {st['affinity_rate']:.2f})")

    n_events = 0
    t0 = time.time()
    for shard in shards:
        for req in shard.requests:
            shard.engine.submit(req)
    if n_rep == 1:
        done = shards[0].engine.run()
    else:
        # concurrent ticks: start every engine's background thread, then
        # park on the per-request done flags (engine queue/slot stats are
        # racy between admission and slot publication)
        for shard in shards:
            shard.engine.start(retain_completed=True)
        done = []
        try:
            deadline = time.time() + 600.0
            all_reqs = [r for s in shards for r in s.requests]
            while time.time() < deadline:
                if all(r.done for r in all_reqs):
                    break
                time.sleep(0.05)
        finally:
            for shard in shards:
                shard.engine.stop()
                done.extend(shard.engine.completed)
    dt = time.time() - t0
    for r in done:
        n_events += len(r.out_tokens)
    print(f"served {len(done)} requests, {n_events} events "
          f"in {dt:.1f}s ({n_events / dt:.1f} events/s)")
    r = done[0]
    names = [V.code_name(t) for t in r.out_tokens[:8]]
    print("sample trajectory:", list(zip(names,
                                         [round(a, 1) for a in r.out_ages[:8]])))


if __name__ == "__main__":
    main()
