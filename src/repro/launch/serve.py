"""Serving launcher: batched trajectory generation via the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch delphi-2m \
        [--requests 16] [--slots 8] [--ckpt runs/delphi]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import SimulatorConfig, generate_dataset
from repro.data import vocab as V
from repro.models import init_params
from repro.serve import BatchedEngine, Request
from repro.train import restore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="delphi-2m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if jax.default_backend() == "cpu":
        cfg = cfg.replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.ckpt:
        params = restore(args.ckpt, params)

    eng = BatchedEngine(params, cfg, slots=args.slots,
                        max_context=cfg.max_seq_len, seed=args.seed)

    # prompts: prefixes of fresh synthetic patients (their known history)
    trajs, _ = generate_dataset(SimulatorConfig(
        n_train=args.requests, n_val=1, seed=args.seed + 17))
    n_events = 0
    t0 = time.time()
    for tok, age in trajs:
        half = max(len(tok) // 2, 1)
        eng.submit(Request(tokens=tok[:half], ages=age[:half],
                           max_new=args.max_new))
    done = eng.run()
    dt = time.time() - t0
    for r in done:
        n_events += len(r.out_tokens)
    print(f"served {len(done)} requests, {n_events} events "
          f"in {dt:.1f}s ({n_events / dt:.1f} events/s)")
    r = done[0]
    names = [V.code_name(t) for t in r.out_tokens[:8]]
    print("sample trajectory:", list(zip(names,
                                         [round(a, 1) for a in r.out_ages[:8]])))


if __name__ == "__main__":
    main()
