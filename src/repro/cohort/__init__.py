"""Cohort-scale scenario engine: population risk sweeps, counterfactual
"what if" queries, and the straight-line parity oracle that gates both.

    from repro.cohort import ScenarioEngine, CounterfactualEdit

    se = ScenarioEngine(backend, max_in_flight=8, seed=0)
    result = se.sweep(patients, n_futures=16, horizon=5.0)
    reports = se.counterfactual(tokens, ages,
                                [CounterfactualEdit("remove", code)])
"""
from repro.cohort.counterfactual import (CounterfactualEdit,
                                         CounterfactualReport, apply_edit,
                                         diff_futures)
from repro.cohort.engine import ScenarioEngine, sweep_uniforms
from repro.cohort.oracle import assert_sweep_parity, oracle_patient_futures
from repro.cohort.schemas import CohortSweepResult, PatientResult

__all__ = [
    "CohortSweepResult", "CounterfactualEdit", "CounterfactualReport",
    "PatientResult", "ScenarioEngine", "apply_edit", "assert_sweep_parity",
    "diff_futures", "oracle_patient_futures", "sweep_uniforms",
]
