"""Cohort-scale scenario engine: population sweeps + counterfactuals.

Drives ``sample_futures`` over thousands of synthetic patients through
any :class:`repro.api.InferenceBackend` with a bounded-concurrency
scheduler: ``max_in_flight`` worker threads pull patient indices from a
locked queue and block on the backend, so an engine-backed sweep keeps
the background loop's slots saturated while a remote sweep overlaps
network round trips.  Per-patient uniforms are derived from
``default_rng([seed, tag, index])``, which makes every sweep result
bit-reproducible regardless of worker interleaving — and bit-identical
to the per-patient foreground ``monte_carlo_risk`` oracle
(:mod:`repro.cohort.oracle`).

Scheduler state is lock-guarded (RL001 ``guarded-by`` discipline); the
worker loop is the subsystem's hot path and carries the RL006 marker —
it must stay free of device->host syncs (all aggregation is numpy over
host lists).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api.schemas import FuturesRequest, FuturesResult
from repro.cohort.counterfactual import (CounterfactualEdit,
                                         CounterfactualReport, apply_edit,
                                         diff_futures)
from repro.cohort.schemas import CohortSweepResult, PatientResult
from repro.core.risk import disease_chapter_map_np, futures_chapter_risk

#: Disambiguates the sweep's uniform streams from ``data.synthetic``'s
#: per-patient simulation streams (both are seeded families under the
#: same user seed; the tag keeps them independent).
_UNIFORMS_TAG = 104729


def sweep_uniforms(seed: int, index: int, n_futures: int, max_new: int,
                   vocab_size: int) -> np.ndarray:
    """The (n_futures, max_new, V) injected uniforms for patient
    ``index`` of a sweep — a pure function of (seed, index), so the
    scenario engine and the straight-line oracle consume identical
    randomness and must agree bit for bit."""
    rng = np.random.default_rng([seed, _UNIFORMS_TAG, index])
    return rng.uniform(
        size=(n_futures, max_new, vocab_size)).astype(np.float32)


def _merge_sharing(dicts: Sequence[Dict]) -> Dict:
    """Roll engine-lifetime cumulative sharing counters up across
    results: numeric values take the max (cumulative counters only
    grow), nested dicts merge recursively, other values take the last."""
    out: Dict = {}
    for d in dicts:
        if not d:
            continue
        for k, v in d.items():
            if isinstance(v, dict):
                out[k] = _merge_sharing([out.get(k) or {}, v])
            elif isinstance(v, (int, float)) and \
                    isinstance(out.get(k), (int, float)):
                out[k] = max(out[k], v)
            else:
                out[k] = v
    return out


class ScenarioEngine:
    """Bounded-concurrency cohort scheduler over one inference backend.

    ``max_in_flight`` caps concurrent in-flight patients.  Each patient
    gets ``retries`` re-submissions on failure inside a
    ``patient_deadline`` wall-clock budget; a patient that exhausts both
    lands in the sweep result as a structured failure instead of
    aborting the cohort.  When the backend wraps a ``BatchedEngine``
    whose background loop is not running, the sweep starts it for the
    duration (concurrent submission into a foreground engine is not
    thread-safe) and stops it after.
    """

    def __init__(self, backend: "InferenceBackend", *,  # noqa: F821
                 max_in_flight: int = 4, seed: int = 0,
                 patient_deadline: float = 300.0, retries: int = 1):
        self.backend = backend
        self.max_in_flight = int(max_in_flight)
        self.seed = int(seed)
        self.patient_deadline = float(patient_deadline)
        self.retries = int(retries)
        self._lock = threading.Lock()
        self._sweep_queue: List[int] = []      # guarded-by: _lock
        self._sweep_inputs: List[Tuple] = []   # guarded-by: _lock
        self._sweep_params: Dict = {}          # guarded-by: _lock
        self._sweep_results: Dict[int, PatientResult] = {}  # guarded-by: _lock

    # -- engine lifecycle ----------------------------------------------------
    def _maybe_start_engine(self) -> bool:
        """Start the wrapped engine's background loop when concurrent
        workers will submit; returns True when this sweep owns the stop."""
        eng = getattr(self.backend, "engine", None)
        if eng is None or not hasattr(eng, "start"):
            return False
        if getattr(eng, "running", False):
            return False
        if self.max_in_flight <= 1:
            return False          # a single worker may drive foreground
        eng.start()
        return True

    def _stop_engine(self) -> None:
        eng = getattr(self.backend, "engine", None)
        if eng is not None and hasattr(eng, "stop"):
            eng.stop()

    def _sharing_snapshot(self) -> Dict:
        eng = getattr(self.backend, "engine", None)
        if eng is None or not hasattr(eng, "pool_stats"):
            return {}
        st = eng.pool_stats()
        return {k: st[k] for k in
                ("cache", "forks", "preemptions", "shared_blocks",
                 "shared_blocks_peak", "cow_copies", "suffix_tokens_saved",
                 "prefix_cache") if k in st}

    # -- the sweep -----------------------------------------------------------
    def sweep(self, patients: Sequence[Tuple], *, n_futures: int = 4,
              max_new: int = 32, horizon: float = 5.0, top: int = 10,
              hist_bins: int = 10) -> CohortSweepResult:
        """Run ``sample_futures`` over every (tokens, ages) history and
        aggregate into a :class:`CohortSweepResult`."""
        patients = list(patients)
        n = len(patients)
        params = {"n_futures": int(n_futures), "max_new": int(max_new),
                  "horizon": float(horizon), "top": int(top)}
        with self._lock:
            self._sweep_queue = list(range(n))[::-1]    # pop() -> ascending
            self._sweep_inputs = patients
            self._sweep_params = params
            self._sweep_results = {}
        owns_engine = self._maybe_start_engine()
        t0 = time.perf_counter()
        try:
            workers = [threading.Thread(target=self._worker, daemon=True,
                                        name=f"cohort-worker-{w}")
                       for w in range(max(1, min(self.max_in_flight, n)))]
            for w in workers:
                w.start()
            for w in workers:
                w.join()
        finally:
            if owns_engine:
                self._stop_engine()
        wall = time.perf_counter() - t0
        with self._lock:
            results = [self._sweep_results[i] for i in range(n)]
        return self._aggregate(results, wall, horizon=horizon,
                               hist_bins=hist_bins)

    def _worker(self) -> None:  # repro-lint: hot-path
        """The sweep loop: pull the next patient index under the lock,
        run it against the backend outside the lock, publish the result.
        Host-only orchestration — no device values cross this frame."""
        while True:
            with self._lock:
                if not self._sweep_queue:
                    return
                i = self._sweep_queue.pop()
                tokens, ages = self._sweep_inputs[i]
                params = dict(self._sweep_params)
            res = self._run_patient(i, tokens, ages, params)
            with self._lock:
                self._sweep_results[i] = res

    def _run_patient(self, index: int, tokens, ages,
                     params: Dict) -> PatientResult:
        """One patient through the backend with deadline + retry."""
        t0 = time.perf_counter()
        deadline = t0 + self.patient_deadline
        uniforms = sweep_uniforms(self.seed, index, params["n_futures"],
                                  params["max_new"],
                                  self.backend.vocab_size)
        last_err: Optional[str] = None
        attempt = 0
        for attempt in range(self.retries + 1):
            if attempt and time.perf_counter() > deadline:
                last_err = (f"deadline: {self.patient_deadline:g}s budget "
                            f"exhausted after {attempt} attempt(s); "
                            f"last error: {last_err}")
                break
            try:
                req = FuturesRequest(
                    tokens=tokens, ages=ages,
                    n_futures=params["n_futures"],
                    max_new=params["max_new"],
                    horizon=params["horizon"], top=params["top"],
                    uniforms=uniforms,
                    request_id=f"cohort-{index}-a{attempt}")
                out = self.backend.sample_futures(req)
                chap = self._patient_chapter_risk(out, params["horizon"])
                return PatientResult(
                    index=index, result=out, chapter_risk=chap,
                    retries=attempt,
                    latency_s=time.perf_counter() - t0)
            except Exception as e:        # noqa: BLE001 — per-patient
                last_err = f"{type(e).__name__}: {e}"   # isolation is the
        return PatientResult(                           # scheduler contract
            index=index, error=last_err, retries=attempt,
            latency_s=time.perf_counter() - t0)

    def _patient_chapter_risk(self, out: FuturesResult,
                              horizon: float) -> np.ndarray:
        """(C,) within-horizon chapter risk for one patient's futures —
        the shared fp32-cutoff host aggregation."""
        traj = out.trajectories
        age0 = (float(traj[0].prompt_ages[-1])
                if traj and traj[0].prompt_ages else 0.0)
        futs = [(t.tokens, t.ages) for t in traj]
        return futures_chapter_risk(futs, age0, horizon,
                                    self.backend.vocab_size)

    def _aggregate(self, results: List[PatientResult], wall: float, *,
                   horizon: float, hist_bins: int) -> CohortSweepResult:
        ok = [p for p in results if p.ok]
        C = int(disease_chapter_map_np(self.backend.vocab_size).max()) + 1
        edges = np.linspace(0.0, 1.0, hist_bins + 1)
        if ok:
            chap = np.stack([p.chapter_risk for p in ok])      # (n_ok, C)
            chapter_mean = chap.mean(axis=0)
            chapter_hist = np.stack(
                [np.histogram(chap[:, c], bins=edges)[0] for c in range(C)])
        else:
            chapter_mean = np.zeros(C)
            chapter_hist = np.zeros((C, hist_bins), np.int64)
        sharing = _merge_sharing(
            [p.result.sharing for p in ok] + [self._sharing_snapshot()])
        return CohortSweepResult(
            horizon=float(horizon), n_patients=len(results),
            n_failed=len(results) - len(ok),
            events_total=sum(p.n_events for p in ok),
            wall_s=wall, chapter_mean=chapter_mean,
            chapter_hist=chapter_hist, hist_edges=edges,
            sharing=sharing, results=results)

    # -- counterfactuals -----------------------------------------------------
    def counterfactual(self, tokens, ages,
                       edits: Sequence[CounterfactualEdit], *,
                       n_futures: int = 8, max_new: int = 32,
                       horizon: float = 5.0, top: int = 10,
                       ) -> List[CounterfactualReport]:
        """Paired baseline-vs-edited futures for each edit of ONE history.

        The baseline runs first so its prefill seeds the engine's prefix
        cache; every edited arm then shares all blocks before its edit
        point (a `PrefixIndex` partial hit — only the suffix prefills).
        All arms consume the SAME injected uniforms (common random
        numbers), so each report's deltas isolate the edit's effect.
        """
        uniforms = sweep_uniforms(self.seed, 0, n_futures, max_new,
                                  self.backend.vocab_size)
        base_req = FuturesRequest(tokens=tokens, ages=ages,
                                  n_futures=n_futures, max_new=max_new,
                                  horizon=horizon, top=top,
                                  uniforms=uniforms)
        baseline = self.backend.sample_futures(base_req)
        reports = []
        for edit in edits:
            t2, a2, shared = apply_edit(tokens, ages, edit)
            edited = self.backend.sample_futures(FuturesRequest(
                tokens=t2, ages=a2, n_futures=n_futures, max_new=max_new,
                horizon=horizon, top=top, uniforms=uniforms))
            reports.append(diff_futures(
                edit, baseline, edited, horizon=horizon,
                vocab_size=self.backend.vocab_size,
                shared_prefix_len=shared, top=top))
        return reports
