"""Straight-line oracle for cohort sweeps — the correctness gate.

The scenario engine's concurrency must be unobservable in results: a
sweep through worker threads, background engine loop, paged blocks,
copy-on-write forks and prefix cache must be *bit-identical* to running
each patient alone through the foreground ``monte_carlo_risk`` oracle in
its engine-parity configuration (``monte_carlo_risk(trajectories=
engine_oracle_trajectories(...))`` — the same compiled executables,
scheduler-free) under the same injected uniforms.  This module
recomputes that per-patient foreground answer and asserts exact
equality event for event, risk item for risk item.

Bit-parity contract (inherited from ``ring_reference_futures``): the
sweep engine must run with the same ``slots``/``max_context`` geometry,
``slots >= n_futures`` so each patient's forks land in one wave, and
enough blocks that no request is preempted (recompute-resume re-prefills
at new shapes and is only semantically aligned).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cohort.engine import sweep_uniforms
from repro.cohort.schemas import CohortSweepResult
from repro.core.risk import (disease_chapter_map, futures_chapter_risk,
                             futures_risk_items, monte_carlo_risk,
                             pack_futures_trajectories)


def oracle_patient_futures(params, cfg, tokens, ages, uniforms, *,
                           max_new: int, slots: Optional[int] = None,
                           max_context: int = 512, **oracle_kw
                           ) -> List[Tuple[List[int], List[float]]]:
    """The per-patient foreground futures through the engine's exact
    compiled decode path, scheduler-free (``ring_reference_futures``),
    as generated (tokens, fp32 ages) suffixes."""
    from repro.serve.prefix import ring_reference_futures
    n = int(np.asarray(uniforms).shape[0])
    futs = ring_reference_futures(
        params, cfg, np.asarray(tokens), np.asarray(ages), n=n,
        max_new=max_new, uniforms=uniforms, slots=slots,
        max_context=max_context, **oracle_kw)
    return [([int(t) for t in ts], [float(a) for a in ags])
            for ts, ags in futs]


def assert_sweep_parity(sweep: CohortSweepResult, params, cfg,
                        patients: Sequence[Tuple], *, seed: int,
                        n_futures: int, max_new: int, horizon: float,
                        top: int = 10, slots: Optional[int] = None,
                        max_context: int = 512,
                        **oracle_kw) -> Dict[str, int]:
    """Assert the sweep is bit-identical to the per-patient oracle.

    For every successful patient: (1) each forked future's generated
    tokens AND ages match the foreground oracle exactly, (2) the
    aggregated ``RiskReport`` equals ``futures_risk_items`` over the
    oracle futures, (3) the per-patient chapter risks equal BOTH the
    shared host aggregation and the in-graph
    ``monte_carlo_risk(trajectories=..., chapter_of=...)`` answer over
    the oracle futures.  ``slots``/``max_context`` must mirror the sweep
    engine's geometry.  Raises ``AssertionError`` on the first
    divergence; returns counters.
    """
    chapter_of = disease_chapter_map(cfg.vocab_size)
    checked = events = 0
    for pr in sweep.results:
        if not pr.ok:
            continue
        tokens, ages = patients[pr.index]
        uniforms = sweep_uniforms(seed, pr.index, n_futures, max_new,
                                  cfg.vocab_size)
        oracle = oracle_patient_futures(
            params, cfg, tokens, ages, uniforms, max_new=max_new,
            slots=slots, max_context=max_context, **oracle_kw)
        got = [(t.tokens, t.ages) for t in pr.result.trajectories]
        assert len(got) == len(oracle), \
            f"patient {pr.index}: {len(got)} futures != {len(oracle)}"
        for j, ((gt, ga), (ot, oa)) in enumerate(zip(got, oracle)):
            assert [int(t) for t in gt] == ot, \
                f"patient {pr.index} future {j}: tokens diverge " \
                f"({list(gt)[:8]}... vs {ot[:8]}...)"
            assert [float(a) for a in ga] == oa, \
                f"patient {pr.index} future {j}: ages diverge"
            events += len(ot)
        age0 = float(np.asarray(ages)[-1])
        want_items = futures_risk_items(oracle, age0, horizon,
                                        cfg.vocab_size, top=top)
        got_items = [(it.token, it.risk) for it in pr.result.risk.items]
        assert got_items == want_items, \
            f"patient {pr.index}: RiskReport diverges from oracle " \
            f"({got_items} vs {want_items})"
        want_chap = futures_chapter_risk(oracle, age0, horizon,
                                         cfg.vocab_size)
        assert np.array_equal(np.asarray(pr.chapter_risk), want_chap), \
            f"patient {pr.index}: chapter risks diverge from host oracle"
        mc = monte_carlo_risk(
            params, cfg, np.asarray(tokens), np.asarray(ages),
            horizon=horizon, chapter_of=chapter_of,
            trajectories=pack_futures_trajectories(tokens, ages, oracle,
                                                   max_new=max_new))
        # The in-graph path accumulates the futures mean in float32;
        # the host oracle means in float64.  Identical indicator sets,
        # so the only slack is one fp32 rounding of the division.
        assert np.allclose(np.asarray(mc["chapter_risk"], np.float64),
                           want_chap, rtol=1e-6, atol=1e-7), \
            f"patient {pr.index}: monte_carlo_risk chapter_risk diverges"
        checked += 1
    return {"patients_checked": checked, "events_checked": events}
