"""Counterfactual edit API — "what if" queries over a patient history.

An edit inserts, removes, or substitutes ONE diagnosis in a (tokens,
ages) history.  Because every event before the edit point is unchanged,
the edited history shares its entire prefix with the baseline: under the
serving engine's prefix cache the edited arm's prefill is a partial hit
that recomputes only the suffix, so N counterfactuals per patient cost
~1 prefill + N suffixes (fork trees of fork trees).

Both arms are sampled under the SAME injected uniforms (common random
numbers), so the paired difference isolates the edit's effect from
sampling noise; the diff lands in a :class:`CounterfactualReport` with
per-chapter risk deltas computed by the shared fp32-cutoff host
aggregation (``core.risk.futures_chapter_risk``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.api.schemas import WIRE_PROTOCOL_VERSION, FuturesResult

EDIT_OPS = ("insert", "remove", "substitute")


@dataclasses.dataclass
class CounterfactualEdit:
    """One diagnosis-level edit of a history.

    ``op="insert"``      add ``code`` at ``age`` (kept age-sorted);
    ``op="remove"``      drop the first occurrence of ``code``;
    ``op="substitute"``  replace the first occurrence of ``code`` with
                         ``new_code`` at the same age.
    """
    op: str
    code: int
    age: Optional[float] = None
    new_code: Optional[int] = None

    def validate(self) -> None:
        if self.op not in EDIT_OPS:
            raise ValueError(f"edit op must be one of {EDIT_OPS}; "
                             f"got {self.op!r}")
        if self.op == "insert" and self.age is None:
            raise ValueError("insert edits need an age")
        if self.op == "substitute" and self.new_code is None:
            raise ValueError("substitute edits need a new_code")

    def describe(self) -> str:
        if self.op == "insert":
            return f"insert code {self.code} at age {self.age:g}"
        if self.op == "remove":
            return f"remove code {self.code}"
        return f"substitute code {self.code} -> {self.new_code}"

    def to_json(self) -> dict:
        d: dict = {"op": self.op, "code": int(self.code)}
        if self.age is not None:
            d["age"] = float(self.age)
        if self.new_code is not None:
            d["new_code"] = int(self.new_code)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "CounterfactualEdit":
        return cls(op=str(d["op"]), code=int(d["code"]),
                   age=(float(d["age"]) if d.get("age") is not None
                        else None),
                   new_code=(int(d["new_code"])
                             if d.get("new_code") is not None else None))


def apply_edit(tokens: Sequence[int], ages: Sequence[float],
               edit: CounterfactualEdit
               ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Edited (tokens, ages) plus the shared-prefix length in events.

    The shared prefix is every event strictly before the edit point —
    exactly the span the engine's ``PrefixIndex`` can partial-hit, so
    ``shared_prefix_len`` is the lower bound on reused prefill work.
    """
    edit.validate()
    toks = [int(t) for t in tokens]
    ags = [float(a) for a in ages]
    if len(toks) != len(ags):
        raise ValueError(f"tokens/ages length mismatch: "
                         f"{len(toks)} vs {len(ags)}")
    if edit.op == "insert":
        pos = len(ags)
        for i, a in enumerate(ags):
            if a > edit.age:
                pos = i
                break
        toks.insert(pos, int(edit.code))
        ags.insert(pos, float(edit.age))
        shared = pos
    else:
        try:
            pos = toks.index(int(edit.code))
        except ValueError:
            raise ValueError(
                f"history has no occurrence of code {edit.code} "
                f"to {edit.op}") from None
        if edit.op == "remove":
            del toks[pos]
            del ags[pos]
        else:
            toks[pos] = int(edit.new_code)
        shared = pos
    if not toks:
        raise ValueError("edit would leave an empty history")
    return (np.asarray(toks, np.int32), np.asarray(ags, np.float32),
            int(shared))


@dataclasses.dataclass
class CounterfactualReport:
    """Paired diff of baseline vs edited futures for ONE patient.

    ``chapter_delta[c] = edited_chapter[c] - baseline_chapter[c]`` —
    the change in P(any code of chapter c occurs within the horizon),
    index 0 the non-disease bucket.  Both arms are cut off at the
    BASELINE patient's last known age + horizon so the comparison window
    is identical even when the edit moves the last event.  ``top_deltas``
    lists the individual codes that moved most (by |delta|).
    """
    edit: CounterfactualEdit
    horizon: float
    shared_prefix_len: int
    baseline: FuturesResult
    edited: FuturesResult
    baseline_chapter: np.ndarray
    edited_chapter: np.ndarray
    top_deltas: List[Tuple[int, float, float, float]]  # token, base, cf, delta

    @property
    def chapter_delta(self) -> np.ndarray:
        return self.edited_chapter - self.baseline_chapter

    def to_json(self) -> dict:
        return {
            "protocol_version": WIRE_PROTOCOL_VERSION,
            "edit": self.edit.to_json(),
            "horizon": float(self.horizon),
            "shared_prefix_len": int(self.shared_prefix_len),
            "baseline_chapter": [float(x) for x in self.baseline_chapter],
            "edited_chapter": [float(x) for x in self.edited_chapter],
            "chapter_delta": [float(x) for x in self.chapter_delta],
            "top_deltas": [
                {"token": int(t), "baseline": float(b), "edited": float(e),
                 "delta": float(d)} for t, b, e, d in self.top_deltas],
            "sharing": self.edited.sharing,
        }


def _code_risk_vector(result: FuturesResult, cutoff: np.float32,
                      vocab_size: int) -> np.ndarray:
    """Full (V,) within-cutoff occurrence frequency over a result's
    futures — the same counting rule as ``core.risk.futures_risk_items``
    but dense, for paired subtraction."""
    n = max(len(result.trajectories), 1)
    counts = np.zeros(vocab_size, np.int64)
    for t in result.trajectories:
        if t.ages:
            seen = {int(tok) for tok, a in zip(t.tokens, t.ages)
                    if np.float32(a) <= cutoff}
        else:
            seen = {int(tok) for tok in t.tokens}
        for tok in seen:
            if 0 <= tok < vocab_size:
                counts[tok] += 1
    return counts / float(n)


def diff_futures(edit: CounterfactualEdit, baseline: FuturesResult,
                 edited: FuturesResult, *, horizon: float, vocab_size: int,
                 shared_prefix_len: int, top: int = 10
                 ) -> CounterfactualReport:
    """Aggregate a paired (baseline, edited) futures draw into a
    :class:`CounterfactualReport` with per-chapter deltas."""
    from repro.core.risk import futures_chapter_risk
    base_traj = baseline.trajectories
    age0 = (float(base_traj[0].prompt_ages[-1])
            if base_traj and base_traj[0].prompt_ages else 0.0)
    cutoff = np.float32(np.float32(age0) + np.float32(horizon))
    futs_b = [(t.tokens, t.ages) for t in baseline.trajectories]
    futs_e = [(t.tokens, t.ages) for t in edited.trajectories]
    chap_b = futures_chapter_risk(futs_b, age0, horizon, vocab_size)
    chap_e = futures_chapter_risk(futs_e, age0, horizon, vocab_size)
    risk_b = _code_risk_vector(baseline, cutoff, vocab_size)
    risk_e = _code_risk_vector(edited, cutoff, vocab_size)
    delta = risk_e - risk_b
    order = np.argsort(-np.abs(delta), kind="stable")[:top]
    top_deltas = [(int(i), float(risk_b[i]), float(risk_e[i]),
                   float(delta[i])) for i in order if delta[i] != 0.0]
    return CounterfactualReport(
        edit=edit, horizon=float(horizon),
        shared_prefix_len=int(shared_prefix_len),
        baseline=baseline, edited=edited,
        baseline_chapter=chap_b, edited_chapter=chap_e,
        top_deltas=top_deltas)
