"""Result schemas for cohort sweeps — pure data, JSON-serializable.

``PatientResult`` is one patient's outcome inside a sweep (the
aggregated :class:`~repro.api.schemas.FuturesResult`, or a structured
failure after the scheduler's retries ran out).  ``CohortSweepResult``
is the population rollup: per-chapter mean risk and risk histograms
(the App's population view), throughput, and the engine's sharing
telemetry.  ``to_json`` emits the summary without per-future
trajectories so a 10k-patient sweep serializes in kilobytes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.api.schemas import WIRE_PROTOCOL_VERSION, FuturesResult


@dataclasses.dataclass
class PatientResult:
    """One patient's slot in a cohort sweep."""
    index: int
    result: Optional[FuturesResult] = None
    chapter_risk: Optional[np.ndarray] = None   # (C,) host aggregation
    error: Optional[str] = None
    retries: int = 0
    latency_s: float = 0.0

    @property
    def ok(self) -> bool:
        return self.error is None and self.result is not None

    @property
    def n_events(self) -> int:
        if self.result is None:
            return 0
        return sum(len(t.tokens) for t in self.result.trajectories)

    def to_json(self) -> dict:
        d: dict = {"index": int(self.index), "ok": self.ok,
                   "retries": int(self.retries),
                   "latency_s": float(self.latency_s),
                   "n_events": int(self.n_events)}
        if self.error is not None:
            d["error"] = str(self.error)
        if self.chapter_risk is not None:
            d["chapter_risk"] = [float(x) for x in self.chapter_risk]
        return d


@dataclasses.dataclass
class CohortSweepResult:
    """Population rollup of one cohort sweep.

    ``chapter_mean``  (C,)    mean per-patient within-horizon chapter risk
    ``chapter_hist``  (C, B)  histogram of per-patient chapter risks over
                              ``hist_edges`` (B+1,) — the population risk
                              distribution per disease chapter
    ``sharing``               pool/prefix telemetry snapshotted at sweep
                              end (engine-lifetime cumulative counters;
                              empty for host-loop backends)
    """
    horizon: float
    n_patients: int
    n_failed: int
    events_total: int
    wall_s: float
    chapter_mean: np.ndarray
    chapter_hist: np.ndarray
    hist_edges: np.ndarray
    sharing: Dict = dataclasses.field(default_factory=dict)
    results: List[PatientResult] = dataclasses.field(default_factory=list)

    @property
    def n_ok(self) -> int:
        return self.n_patients - self.n_failed

    @property
    def patients_per_s(self) -> float:
        return self.n_ok / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def events_per_s(self) -> float:
        return self.events_total / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        """Exact+partial prefix-cache hit rate over the engine lifetime
        (0.0 when the backend exposes no prefix telemetry)."""
        pc = self.sharing.get("prefix_cache") or {}
        hits = pc.get("hits", 0) + pc.get("partial_hits", 0)
        total = hits + pc.get("misses", 0)
        return hits / total if total else 0.0

    def to_json(self) -> dict:
        return {
            "protocol_version": WIRE_PROTOCOL_VERSION,
            "horizon": float(self.horizon),
            "n_patients": int(self.n_patients),
            "n_failed": int(self.n_failed),
            "events_total": int(self.events_total),
            "wall_s": float(self.wall_s),
            "patients_per_s": float(self.patients_per_s),
            "events_per_s": float(self.events_per_s),
            "prefix_hit_rate": float(self.prefix_hit_rate),
            "chapter_mean": [float(x) for x in self.chapter_mean],
            "chapter_hist": [[int(c) for c in row]
                             for row in self.chapter_hist],
            "hist_edges": [float(x) for x in self.hist_edges],
            "sharing": self.sharing,
            "patients": [p.to_json() for p in self.results],
        }
