"""Structured error taxonomy for every inference surface.

One vocabulary of machine-readable error codes shared by the in-process
backends, the HTTP front-end (``repro.serve.server``) and the wire client
(``repro.api.RemoteBackend``): each :class:`ApiError` carries a stable
``code`` plus the HTTP status the server maps it to, and serializes to the
canonical JSON error body

    {"error": {"code": "<code>", "message": "<human text>"}}

so a validation failure raised by ``InferenceBackend._validate`` surfaces as
the *same exception type* whether the backend lives in-process or across the
network.  ``ApiError`` subclasses ``ValueError``, so every pre-existing
``pytest.raises(ValueError, ...)`` contract over the SDK/client keeps
holding.
"""
from __future__ import annotations

from typing import Dict, Optional, Type

__all__ = [
    "ApiError", "EmptyTrajectoryError", "TooLongError", "AgesRequiredError",
    "AgesLengthMismatchError", "RngNotSerializableError",
    "UnsupportedOverrideError", "InvalidRequestError", "ProtocolVersionError",
    "UnknownEndpointError", "RequestTimeoutError", "RequestCancelledError",
    "ReplicaUnavailableError", "InternalServerError", "error_from_code",
    "error_from_json",
]


class ApiError(ValueError):
    """Base of the taxonomy: a ``ValueError`` with a stable wire identity.

    ``code`` is the machine-readable contract (clients branch on it, tests
    assert it, the server maps it 1:1 to ``http_status``); ``message`` is
    human text and may change freely between releases.
    """
    code: str = "bad_request"
    http_status: int = 400

    # code -> subclass, filled by __init_subclass__: the single source of
    # truth for reconstructing typed errors from wire bodies
    registry: Dict[str, Type["ApiError"]] = {}

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        ApiError.registry[cls.code] = cls

    def __init__(self, message: str, *, code: Optional[str] = None,
                 http_status: Optional[int] = None):
        super().__init__(message)
        if code is not None:
            self.code = code
        if http_status is not None:
            self.http_status = http_status

    @property
    def message(self) -> str:
        return str(self.args[0]) if self.args else ""

    def to_json(self) -> dict:
        """The canonical wire body (the server sends exactly this)."""
        return {"error": {"code": self.code, "message": self.message}}


# -- validation failures (InferenceBackend._validate) ------------------------
class EmptyTrajectoryError(ApiError):
    code = "empty_trajectory"


class TooLongError(ApiError):
    code = "too_long"


class AgesRequiredError(ApiError):
    code = "ages_required"


class AgesLengthMismatchError(ApiError):
    code = "ages_length_mismatch"


# -- request-construction / serialization failures ---------------------------
class RngNotSerializableError(ApiError):
    """``GenerateRequest.rng`` holds live host PRNG state — it cannot cross a
    process boundary; inject ``uniforms`` (or pass ``seed``) instead."""
    code = "rng_not_serializable"


class UnsupportedOverrideError(ApiError):
    """Per-request knob the serving backend compiled in at construction."""
    code = "unsupported_override"


class InvalidRequestError(ApiError):
    """Malformed body: not JSON, wrong types, or missing required fields."""
    code = "invalid_request"


class ProtocolVersionError(ApiError):
    """Client and server speak different wire-protocol versions."""
    code = "protocol_version_mismatch"
    http_status = 409


# -- server-side conditions ---------------------------------------------------
class UnknownEndpointError(ApiError):
    code = "unknown_endpoint"
    http_status = 404


class RequestTimeoutError(ApiError):
    code = "timeout"
    http_status = 504


class RequestCancelledError(ApiError):
    """The request was cancelled (``POST /v1/cancel`` / ``engine.cancel``)
    before it completed; any partial output was discarded server-side.  SSE
    streams signal this as a terminal ``cancelled`` frame."""
    code = "request_cancelled"
    http_status = 409


class ReplicaUnavailableError(ApiError):
    """The serving replica cannot be reached.  Raised client-side by
    ``RemoteBackend`` when the server is unreachable at the transport level
    (connect failure, connection dropped mid-response), and served by the
    multi-replica router (``repro.serve.router``) when no healthy replica
    remains to take the request — including a retried idempotent call whose
    every candidate failed, and a pinned stream whose replica died
    mid-flight (terminal SSE ``error`` frame carrying this code)."""
    code = "replica_unavailable"
    http_status = 503


class InternalServerError(ApiError):
    code = "internal"
    http_status = 500


def error_from_code(code: str, message: str) -> ApiError:
    """Reconstruct the typed error for a wire ``code`` (unknown codes fall
    back to a plain ``ApiError`` carrying the code verbatim, so a newer
    server never crashes an older client)."""
    cls = ApiError.registry.get(code)
    if cls is None:
        return ApiError(message, code=code)
    return cls(message)


def error_from_json(body: dict) -> ApiError:
    """Inverse of :meth:`ApiError.to_json` (tolerates malformed bodies)."""
    err = body.get("error", {}) if isinstance(body, dict) else {}
    return error_from_code(str(err.get("code", "internal")),
                           str(err.get("message", "unknown server error")))
