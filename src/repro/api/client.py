"""Unified inference client: one facade, three pluggable backends.

``Client`` is the single public entry point over every inference surface the
repo grew — the FAIR artifact runtime, the batched serving engine, and
in-process params — with one request/result vocabulary (``repro.api.schemas``)
and one host-side eq.-1 sampler (``repro.core.sampler.sample_next_event_np``)
so trajectories are bit-comparable across backends under injected uniforms:

* :class:`ArtifactBackend` — wraps ``sdk.runtime.Runtime``.  Spec-v2
  artifacts generate via **prefill-then-decode** (KV cache threaded through
  the exported decode graph, O(1) model work per token); v1 artifacts fall
  back to the paper-faithful full-graph-per-token loop.
* :class:`EngineBackend` — wraps ``serve.BatchedEngine`` for batched /
  streaming server-side use (in-graph eq. 1 sampling, one host sync per
  tick).
* :class:`LocalBackend` — in-process params + ``core.sampler`` (in-graph
  batched generation; streaming via the same prefill/decode functions the
  exporter serializes).
* :class:`repro.api.remote.RemoteBackend` — the same surface over the
  versioned JSON/SSE wire protocol against a ``repro.serve.server`` — the
  network as a fourth pluggable backend (``Client.connect(url)``).

``sdk.InferenceSession`` is a thin compatibility shim over ``Client``.
"""
from __future__ import annotations

import functools
from typing import (TYPE_CHECKING, Iterator, List, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.risk import analytic_next_event_risk_np
from repro.core.sampler import sample_next_event_np
from repro.sdk.runtime import Runtime
from repro.api.errors import (AgesLengthMismatchError, AgesRequiredError,
                              EmptyTrajectoryError, InvalidRequestError,
                              TooLongError, UnsupportedOverrideError)
from repro.api.schemas import (FuturesRequest, FuturesResult, GenerateRequest,
                               RiskItem, RiskReport, TrajectoryEvent,
                               TrajectoryResult)

if TYPE_CHECKING:                        # heavy deps stay lazy at runtime:
    from repro.serve.engine import BatchedEngine   # engine/local backends
    from repro.serve.engine import Request as EngineRequest  # noqa: F401


# ---------------------------------------------------------------------------
# Backend base: shared validation, host generation loop, result assembly
# ---------------------------------------------------------------------------
class InferenceBackend:
    """Common surface all backends implement.

    Subclasses set ``name``, ``seq_len``, ``vocab_size``, ``has_ages``,
    ``max_age``, ``death_token`` and implement ``logits`` plus either
    ``_event_stream`` (host-loop backends) or override ``generate`` /
    ``stream`` directly.  Concrete subclasses self-register by ``name``
    (``InferenceBackend.registry``) — how ``repro.api`` knows its four
    backends (artifact / engine / local / remote) without hard-coding them.
    """
    name = "abstract"
    seq_len: int
    vocab_size: int
    has_ages: bool
    max_age: float
    death_token: int

    registry: dict = {}

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        name = cls.__dict__.get("name")
        if name and name != "abstract":
            InferenceBackend.registry[name] = cls

    # -- validation (structured error taxonomy; every error is a ValueError
    #    subclass, so the legacy SDK contract still holds) -------------------
    def _validate(self, tokens: Sequence[int],
                  ages: Optional[Sequence[float]]) -> None:
        if len(tokens) == 0:
            raise EmptyTrajectoryError(
                "empty trajectory: pass at least one event token")
        if len(tokens) > self.seq_len:
            raise TooLongError(f"trajectory longer than graph axis "
                               f"({self.seq_len})")
        if self.has_ages:
            if ages is None:
                raise AgesRequiredError(
                    "this model's signature declares an 'ages' input: pass "
                    "ages alongside tokens")
            if len(ages) != len(tokens):
                raise AgesLengthMismatchError(
                    f"ages/tokens length mismatch: "
                    f"{len(ages)} vs {len(tokens)}")

    def _validate_request(self, req: GenerateRequest) -> None:
        """Full request validation: trajectory inputs + the uniforms
        contract (row i feeds sampled event i, so the array must cover
        max_new rows at the backend's vocab width).  Catching a bad shape
        here keeps it a structured 400 instead of an IndexError inside a
        backend loop — on the engine, one short array would otherwise fail
        every in-flight request."""
        self._validate(req.tokens, req.ages)
        if req.uniforms is not None:
            u = np.asarray(req.uniforms)
            if u.ndim != 2 or u.shape[0] < req.max_new \
                    or u.shape[1] != self.vocab_size:
                raise InvalidRequestError(
                    f"uniforms must have shape (>= max_new, vocab_size) = "
                    f"(>= {req.max_new}, {self.vocab_size}); got "
                    f"{tuple(u.shape)}")

    def _pad_inputs(self, tokens: Sequence[int],
                    ages: Optional[Sequence[float]]) -> Tuple[np.ndarray, ...]:
        """Right-pad to the fixed graph axis (ages repeat the last value)."""
        self._validate(tokens, ages)
        S = self.seq_len
        t = np.zeros((1, S), np.int32)
        t[0, :len(tokens)] = tokens
        if not self.has_ages:
            return (t,)
        a = np.zeros((1, S), np.float32)
        a[0, :len(ages)] = ages
        a[0, len(ages):] = ages[-1]
        return t, a

    def _term(self, req: GenerateRequest) -> Tuple[float, int]:
        max_age = self.max_age if req.max_age is None else req.max_age
        death = self.death_token if req.death_token is None else req.death_token
        return max_age, death

    # -- the ONE host-side generation loop ----------------------------------
    def _host_events(self, req: GenerateRequest, next_logits
                     ) -> Iterator[TrajectoryEvent]:
        """Iterative client-side generation (the App's right-hand panel).

        ``next_logits(toks, ags, state) -> (logits (V,), state)`` abstracts
        full-graph recompute (state unused) vs prefill-then-decode (state
        carries the KV cache); the sampling/termination semantics here are
        the single host-side source of truth, shared by every backend and by
        the ``InferenceSession`` shim.
        """
        max_age, death = self._term(req)
        toks = [int(t) for t in req.tokens]
        ags = ([float(a) for a in req.ages] if req.ages is not None else [])
        rng = req.rng if req.rng is not None else np.random.default_rng(req.seed)
        state = None
        n = 0
        for i in range(req.max_new):
            if len(toks) >= self.seq_len:
                break
            logits, state = next_logits(toks, ags, state)
            lg = np.asarray(logits).reshape(-1).astype(np.float64)
            u = (req.uniforms[i] if req.uniforms is not None
                 else rng.uniform(size=self.vocab_size))
            if self.has_ages:
                evt, tmin = sample_next_event_np(lg, u)      # paper eq. 1
                age = ags[-1] + tmin
                if age > max_age:       # censored BEFORE emitting (C2/C3)
                    break
                toks.append(evt)
                ags.append(age)
                yield TrajectoryEvent(index=n, token=evt, age=age)
                n += 1
                if evt == death:
                    break
            else:                       # generic LM: Gumbel-max categorical
                g = -np.log(-np.log(np.clip(u, 1e-12, 1 - 1e-12)))
                evt = int(np.argmax(lg + g))
                toks.append(evt)
                yield TrajectoryEvent(index=n, token=evt)
                n += 1

    def _prefill_decode_stepper(self, prefill, decode):
        """One prefill-then-decode state machine for every backend that owns
        a (prefill, decode) pair — the artifact runtime's deserialized
        graphs and LocalBackend's jits of the very functions the exporter
        serializes.  ``prefill(padded_inputs, last_index) -> (logits (1, V),
        cache)``; ``decode(cache, token, age_or_None, step) -> (logits
        (1, V), cache)``.
        """
        def next_fn(toks, ags, state):
            if state is None:
                inputs = self._pad_inputs(toks,
                                          ags if self.has_ages else None)
                lg, cache = prefill(inputs, len(toks) - 1)
                return np.asarray(lg)[0], (cache, len(toks))
            cache, step = state
            lg, cache = decode(cache, toks[-1],
                               ags[-1] if self.has_ages else None, step)
            return np.asarray(lg)[0], (cache, step + 1)
        return next_fn

    def _result(self, req: GenerateRequest,
                events: List[TrajectoryEvent]) -> TrajectoryResult:
        return TrajectoryResult(
            tokens=[e.token for e in events],
            ages=[e.age for e in events if e.age is not None],
            prompt_tokens=[int(t) for t in req.tokens],
            prompt_ages=([float(a) for a in req.ages]
                         if req.ages is not None else []),
            backend=self.name)

    # -- public backend surface ---------------------------------------------
    def logits(self, tokens: Sequence[int],
               ages: Optional[Sequence[float]] = None) -> np.ndarray:
        """Next-event logits for the trajectory so far: (V,) fp32."""
        raise NotImplementedError

    def _event_stream(self, req: GenerateRequest) -> Iterator[TrajectoryEvent]:
        raise NotImplementedError

    def stream(self, req: GenerateRequest) -> Iterator[TrajectoryEvent]:
        self._validate_request(req)
        return self._event_stream(req)

    def generate(self, req: GenerateRequest) -> TrajectoryResult:
        return self._result(req, list(self.stream(req)))

    def generate_batch(self, reqs: Sequence[GenerateRequest]
                       ) -> List[TrajectoryResult]:
        return [self.generate(r) for r in reqs]

    def cancel(self, request_id: str) -> bool:
        """Cancel an in-flight ``generate``/``stream`` by its
        ``GenerateRequest.request_id``.  Host-loop backends run the model on
        the caller's thread and have nothing concurrent to cancel — only the
        engine (slot eviction + block free) and remote (``POST /v1/cancel``)
        backends override this.  Returns False when nothing was cancelled."""
        return False

    def risk(self, tokens: Sequence[int],
             ages: Optional[Sequence[float]] = None, *,
             horizon: float = 5.0, top: int = 10) -> RiskReport:
        """Closed-form within-horizon next-event risks, highest first.

        P(next = i, t <= h) = softmax(logits)_i * (1 - e^{-Lambda h}).
        Backend-level (not on ``Client``) so remote backends can answer on
        the server, where the logits live.
        """
        lg = self.logits(tokens, ages)
        risk = analytic_next_event_risk_np(lg, horizon)
        order = np.argsort(-risk)[:top]
        return RiskReport(
            horizon=horizon,
            items=[RiskItem(token=int(i), risk=float(risk[i]))
                   for i in order],
            backend=self.name)

    # -- Monte-Carlo futures (the morbidity-risk workload) -------------------
    def _validate_futures(self, req: FuturesRequest) -> None:
        self._validate(req.tokens, req.ages)
        if req.n_futures < 1:
            raise InvalidRequestError(
                f"n_futures must be >= 1; got {req.n_futures}")
        if req.uniforms is not None:
            u = np.asarray(req.uniforms)
            if u.ndim != 3 or u.shape[0] < req.n_futures \
                    or u.shape[1] < req.max_new \
                    or u.shape[2] != self.vocab_size:
                raise InvalidRequestError(
                    f"futures uniforms must have shape (>= n_futures, "
                    f">= max_new, vocab_size) = (>= {req.n_futures}, "
                    f">= {req.max_new}, {self.vocab_size}); got "
                    f"{tuple(u.shape)}")

    def _futures_result(self, req: FuturesRequest,
                        results: List[TrajectoryResult]) -> FuturesResult:
        """Aggregate N futures into the shared within-horizon RiskReport —
        ONE host-side aggregation (``core.risk.futures_risk_items``) for
        every backend, so identical trajectories give identical reports."""
        from repro.core.risk import futures_risk_items
        # len() guard, not truthiness: ages may arrive as a numpy array
        age0 = (float(req.ages[-1])
                if req.ages is not None and len(req.ages) else 0.0)
        items = futures_risk_items(
            [(r.tokens, r.ages) for r in results], age0, req.horizon,
            self.vocab_size, top=req.top)
        report = RiskReport(
            horizon=req.horizon,
            items=[RiskItem(token=t, risk=p) for t, p in items],
            backend=self.name)
        return FuturesResult(risk=report, trajectories=results,
                             n_futures=req.n_futures, backend=self.name)

    def sample_futures(self, req: FuturesRequest) -> FuturesResult:
        """N stochastic continuations of one history, aggregated into a
        within-horizon ``RiskReport``.  Host-loop backends generate the
        futures sequentially through their ordinary decode path (the
        artifact client's paper-faithful fallback); the engine overrides
        this with prefix-shared ``fork`` admission and the local backend
        with one vectorized in-graph call."""
        self._validate_futures(req)
        rng = np.random.default_rng(req.seed)
        results = []
        for i in range(req.n_futures):
            u = (np.asarray(req.uniforms[i]) if req.uniforms is not None
                 else rng.uniform(
                     size=(req.max_new, self.vocab_size)).astype(np.float32))
            results.append(self.generate(GenerateRequest(
                tokens=req.tokens, ages=req.ages, max_new=req.max_new,
                uniforms=u)))
        return self._futures_result(req, results)


# ---------------------------------------------------------------------------
# Artifact backend (the FAIR client path)
# ---------------------------------------------------------------------------
class ArtifactBackend(InferenceBackend):
    """Client over an exported artifact directory (``sdk.runtime.Runtime``).

    Spec-v2 artifacts default to prefill-then-decode generation: one prefill
    over the prompt, then one KV-cached decode_step per generated token —
    instead of re-running the O(S·V) full graph per token (the v1 path, kept
    as ``use_decode_graph=False`` and as the automatic v1 fallback).
    """
    name = "artifact"

    def __init__(self, artifact_dir: str, *,
                 use_decode_graph: Optional[bool] = None):
        self.runtime = Runtime(artifact_dir)
        m = self.runtime.manifest
        sig = m["signature"]
        self.seq_len = int(sig["inputs"][0]["shape"][1])
        self.vocab_size = int(sig["outputs"][0]["shape"][2])
        self.has_ages = any(i["name"] == "ages" for i in sig["inputs"])
        term = m.get("sampling", {}).get("termination", {})
        self.death_token = int(term.get("death_token", 1))
        self.max_age = float(term.get("max_age_years", 85.0))
        if use_decode_graph is None:
            use_decode_graph = self.runtime.has_decode_graph
        elif use_decode_graph and not self.runtime.has_decode_graph:
            raise ValueError(
                f"artifact {artifact_dir!r} is spec "
                f"{self.runtime.spec_version} and ships no decode graph; "
                f"re-export with spec v2 or pass use_decode_graph=False")
        self.use_decode_graph = bool(use_decode_graph)

    def logits(self, tokens, ages=None):
        inputs = self._pad_inputs(tokens, ages)
        out = self.runtime.run(*inputs)                  # (1, S, V)
        return out[0, len(tokens) - 1]

    def _next_full(self, toks, ags, state):
        return self.logits(toks, ags if self.has_ages else None), None

    def _next_decode_fn(self):
        def prefill(inputs, last):
            return self.runtime.prefill(*inputs,
                                        np.asarray([last], np.int32))

        def decode(cache, token, age, step):
            args: List[np.ndarray] = [np.asarray([[token]], np.int32)]
            if age is not None:
                args.append(np.asarray([[age]], np.float32))
            args.append(np.asarray([step], np.int32))
            return self.runtime.decode_step(cache, *args)

        return self._prefill_decode_stepper(prefill, decode)

    def _event_stream(self, req):
        step_fn = (self._next_decode_fn() if self.use_decode_graph
                   else self._next_full)
        return self._host_events(req, step_fn)


# ---------------------------------------------------------------------------
# Local backend (in-process params + core.sampler)
# ---------------------------------------------------------------------------
class LocalBackend(InferenceBackend):
    """In-process inference: parameters + the core in-graph sampler.

    ``generate`` runs the batched in-graph generator (``lax.fori_loop`` over
    KV-cached decode steps); ``stream`` jits the same prefill/decode functions
    the exporter serializes, so the local decode path and the artifact decode
    path are one graph by construction.
    """
    name = "local"

    def __init__(self, params, cfg: ModelConfig, *,
                 seq_len: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.seq_len = int(seq_len or cfg.max_seq_len)
        if self.seq_len > cfg.max_seq_len:
            raise ValueError(f"seq_len={self.seq_len} exceeds "
                             f"cfg.max_seq_len={cfg.max_seq_len}")
        self.vocab_size = cfg.vocab_size
        self.has_ages = cfg.age_encoding
        self.max_age = cfg.max_age
        self.death_token = cfg.death_token
        from repro.sdk.export import build_inference_fns
        fns = build_inference_fns(cfg, self.seq_len)
        p_spec = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
        fns["resolve"](p_spec)          # bind the cache treedef for decode
        self._full = jax.jit(fns["full"])
        self._prefill = jax.jit(fns["prefill"])
        self._decode = jax.jit(fns["decode"])

    def logits(self, tokens, ages=None):
        inputs = self._pad_inputs(tokens, ages)
        out = np.asarray(self._full(self.params, *inputs))
        return out[0, len(tokens) - 1]

    def _next_decode_fn(self):
        def prefill(inputs, last):
            return self._prefill(self.params, *inputs,
                                 jnp.asarray([last], jnp.int32))

        def decode(cache, token, age, step):
            args: List = [jnp.asarray([[token]], jnp.int32)]
            if age is not None:
                args.append(jnp.asarray([[age]], jnp.float32))
            args.append(jnp.asarray([step], jnp.int32))
            return self._decode(self.params, list(cache), *args)

        return self._prefill_decode_stepper(prefill, decode)

    def _event_stream(self, req):
        return self._host_events(req, self._next_decode_fn())

    def generate(self, req: GenerateRequest) -> TrajectoryResult:
        # host decode loop for generic LMs (no eq.-1 in-graph generator) and
        # for host-rng requests (the in-graph path draws from PRNGKey(seed),
        # which would silently ignore req.rng)
        if not self.has_ages or req.rng is not None:
            return super().generate(req)
        self._validate_request(req)
        max_age, death = self._term(req)
        S0 = len(req.tokens)
        t = jnp.asarray(np.asarray(req.tokens, np.int32)[None])
        a = jnp.asarray(np.asarray(req.ages, np.float32)[None])
        u = (jnp.asarray(req.uniforms)[None]
             if req.uniforms is not None else None)
        from repro.core.sampler import generate_trajectories
        out = generate_trajectories(
            self.params, self.cfg, t, a, jax.random.PRNGKey(req.seed),
            max_new=req.max_new, max_age=max_age, death_token=death,
            uniforms=u)
        n = int(out["n_generated"][0])
        return TrajectoryResult(
            tokens=np.asarray(out["tokens"][0, S0:S0 + n]).tolist(),
            ages=[float(x) for x in np.asarray(out["ages"][0, S0:S0 + n])],
            prompt_tokens=[int(x) for x in req.tokens],
            prompt_ages=[float(x) for x in req.ages],
            backend=self.name)

    def sample_futures(self, req: FuturesRequest) -> FuturesResult:
        """Vectorized Monte-Carlo futures: all N samples batched through
        ONE jitted ``generate_trajectories`` call (the ``core.risk.
        monte_carlo_risk`` sampling path) instead of N sequential decode
        loops.  Generic-LM configs fall back to the host loop."""
        if not self.has_ages:
            return super().sample_futures(req)
        self._validate_futures(req)
        from repro.core.sampler import generate_trajectories_jit
        N, S0 = req.n_futures, len(req.tokens)
        t = jnp.broadcast_to(
            jnp.asarray(np.asarray(req.tokens, np.int32))[None], (N, S0))
        a = jnp.broadcast_to(
            jnp.asarray(np.asarray(req.ages, np.float32))[None], (N, S0))
        u = None
        if req.uniforms is not None:
            u = jnp.asarray(np.asarray(
                req.uniforms, np.float32)[:N, :req.max_new])
        out = generate_trajectories_jit(
            self.params, self.cfg, t, a, jax.random.PRNGKey(req.seed),
            max_new=req.max_new, uniforms=u)
        n_gen = np.asarray(out["n_generated"])
        toks = np.asarray(out["tokens"])
        ags = np.asarray(out["ages"])
        results = [TrajectoryResult(
            tokens=toks[j, S0:S0 + n_gen[j]].tolist(),
            ages=[float(x) for x in ags[j, S0:S0 + n_gen[j]]],
            prompt_tokens=[int(x) for x in req.tokens],
            prompt_ages=[float(x) for x in req.ages],
            backend=self.name) for j in range(N)]
        return self._futures_result(req, results)


# ---------------------------------------------------------------------------
# Engine backend (batched / streaming serving)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("cfg",))
def _full_logits_jit(params, cfg: ModelConfig, tokens, ages):
    from repro.models import forward
    batch = {"tokens": tokens}
    if cfg.age_encoding:
        batch["ages"] = ages
    return forward(params, cfg, batch, mode="train")["logits"]


class EngineBackend(InferenceBackend):
    """Client over the device-resident continuous-batching engine.

    Termination knobs (max_age / death_token / temperature / seed) are baked
    into the engine's compiled tick at construction, so per-request overrides
    raise instead of being silently ignored — build the engine from a
    ``cfg.replace(...)`` to change them.

    Works in two modes: *foreground* (this thread drives ``engine.run()`` /
    ``engine.step()`` — the library default) and *background* (the engine is
    ticking on its own thread via ``engine.start()``, as under the HTTP
    front-end — requests are enqueued and this thread blocks on the
    request's completion hooks, so many handler threads share one engine).
    """
    name = "engine"

    #: background mode: max seconds to wait for the loop thread to finish a
    #: submitted request before failing it with a structured timeout
    request_timeout: float = 300.0

    def __init__(self, engine: BatchedEngine):
        self.engine = engine
        cfg = engine.cfg
        self.cfg = cfg
        self.params = engine.params
        self.seq_len = engine.max_context
        self.vocab_size = cfg.vocab_size
        self.has_ages = cfg.age_encoding
        self.max_age = cfg.max_age
        self.death_token = cfg.death_token

    @classmethod
    def create(cls, params, cfg: ModelConfig, **engine_kwargs
               ) -> "EngineBackend":
        from repro.serve.engine import BatchedEngine
        return cls(BatchedEngine(params, cfg, **engine_kwargs))

    def _check_overrides(self, req: GenerateRequest) -> None:
        if req.max_age is not None and req.max_age != self.max_age:
            raise UnsupportedOverrideError(
                f"EngineBackend termination is compiled into the tick: "
                f"requested max_age={req.max_age} but the engine was built "
                f"with {self.max_age} — construct the engine from "
                f"cfg.replace(max_age=...)")
        if req.death_token is not None and req.death_token != self.death_token:
            raise UnsupportedOverrideError(
                f"EngineBackend death_token is fixed at construction "
                f"({self.death_token}); got {req.death_token}")
        if req.rng is not None:
            raise UnsupportedOverrideError(
                "EngineBackend samples in-graph: pass `uniforms` for "
                "determinism, or seed the engine")
        if req.uniforms is None and req.seed != 0:
            raise UnsupportedOverrideError(
                f"EngineBackend draws from the engine's construction-time "
                f"PRNG stream; per-request seed={req.seed} would be "
                f"silently ignored — inject `uniforms`, or build the "
                f"engine with seed=...")

    def _engine_request(self, req: GenerateRequest, **kw) -> "EngineRequest":
        self._validate_request(req)
        self._check_overrides(req)
        return self._build_engine_request(req, **kw)

    def _build_engine_request(self, req: GenerateRequest, **kw
                              ) -> "EngineRequest":
        """Construction only — callers that validated already (the eager
        ``stream`` wrapper) skip the second pass."""
        from repro.serve.engine import Request as EngineRequest
        return EngineRequest(
            tokens=np.asarray(req.tokens, np.int32),
            ages=(np.asarray(req.ages, np.float32)
                  if req.ages is not None else None),
            max_new=req.max_new, uniforms=req.uniforms,
            request_id=req.request_id, **kw)

    def cancel(self, request_id: str) -> bool:
        """Propagate cancellation into the engine: the request leaves its
        slot (paged blocks freed) and its waiters unblock with a structured
        ``request_cancelled`` error."""
        return self.engine.cancel(request_id)

    def logits(self, tokens, ages=None):
        self._validate(tokens, ages)
        # this backend's prompt axis is the engine ring (max_context), which
        # may exceed cfg.max_seq_len: pad to whichever is larger so long
        # prompts the engine accepts don't overflow the padded buffer
        S = max(self.cfg.max_seq_len, len(tokens))
        t = np.zeros((1, S), np.int32)
        t[0, :len(tokens)] = tokens
        a = np.zeros((1, S), np.float32)
        if self.has_ages:
            a[0, :len(ages)] = ages
            a[0, len(ages):] = ages[-1]
        out = np.asarray(_full_logits_jit(self.params, self.cfg,
                                          jnp.asarray(t), jnp.asarray(a)))
        return out[0, len(tokens) - 1]

    def _finish(self, req: GenerateRequest, er: "EngineRequest"
                ) -> TrajectoryResult:
        if er.error is not None:
            raise er.error
        if not er.done:
            raise RuntimeError("engine stopped before completing the "
                               "request (max_ticks exhausted?)")
        return TrajectoryResult(
            tokens=list(er.out_tokens),
            ages=[float(a) for a in er.out_ages],
            prompt_tokens=[int(t) for t in req.tokens],
            prompt_ages=([float(a) for a in req.ages]
                         if req.ages is not None else []),
            backend=self.name)

    def generate_batch(self, reqs: Sequence[GenerateRequest]
                       ) -> List[TrajectoryResult]:
        pairs = [(r, self._engine_request(r)) for r in reqs]
        if self.engine.running:
            # background mode: the loop thread ticks; park on completion
            import threading
            from repro.api.errors import RequestTimeoutError
            waits = []
            for _, er in pairs:
                evt = threading.Event()
                er.on_done = lambda _r, _evt=evt: _evt.set()
                waits.append(evt)
            for _, er in pairs:
                self.engine.submit(er)
            for evt in waits:
                if not evt.wait(self.request_timeout):
                    raise RequestTimeoutError(
                        f"engine did not complete the request within "
                        f"{self.request_timeout}s")
        else:
            for _, er in pairs:
                self.engine.submit(er)
            self.engine.run()
        return [self._finish(req, er) for req, er in pairs]

    def generate(self, req: GenerateRequest) -> TrajectoryResult:
        return self.generate_batch([req])[0]

    def sample_futures(self, req: FuturesRequest) -> FuturesResult:
        """Monte-Carlo futures through the engine's prefix-sharing ``fork``:
        ONE prefill of the history (a held parent slot), then N decode
        slots sharing every full prefix block by reference — the partial
        tail copy-on-writes per fork — so N futures cost ~1 prefill and
        ~1 prefix of KV instead of N.  Bit-identical to the vectorized
        ``monte_carlo_risk`` oracle under injected uniforms (ring and
        paged caches alike; the ring engine forks by row copy and simply
        forgoes the memory savings).  The result carries the pool's
        sharing telemetry in ``FuturesResult.sharing`` — engine-lifetime
        cumulative counters snapshotted at completion, not per-request
        deltas."""
        self._validate_futures(req)
        if req.uniforms is None and req.seed != 0:
            # mirror the generate() contract: the engine's in-graph RNG
            # would silently ignore a per-request seed — draw the uniforms
            # host-side from it instead, preserving determinism
            rng = np.random.default_rng(req.seed)
            uniforms = rng.uniform(
                size=(req.n_futures, req.max_new,
                      self.vocab_size)).astype(np.float32)
        else:
            uniforms = req.uniforms
        children = self.engine.sample_futures(
            np.asarray(req.tokens, np.int32),
            (np.asarray(req.ages, np.float32)
             if req.ages is not None else None),
            n=req.n_futures, max_new=req.max_new, uniforms=uniforms,
            request_id=req.request_id, wait_timeout=self.request_timeout)
        results = []
        for c in children:
            if c.error is not None:
                raise c.error
            if not c.done:
                raise RuntimeError("engine stopped before completing a "
                                   "forked future")
            results.append(TrajectoryResult(
                tokens=list(c.out_tokens),
                ages=[float(a) for a in c.out_ages],
                prompt_tokens=[int(t) for t in req.tokens],
                prompt_ages=([float(a) for a in req.ages]
                             if req.ages is not None else []),
                backend=self.name))
        out = self._futures_result(req, results)
        st = self.engine.pool_stats()
        out.sharing = {k: st[k] for k in
                       ("cache", "forks", "preemptions", "shared_blocks",
                        "shared_blocks_peak", "cow_copies", "prefix_cache")
                       if k in st}
        return out

    def stream(self, req: GenerateRequest) -> Iterator[TrajectoryEvent]:
        # non-generator wrapper so validation raises HERE, like the other
        # backends — not lazily at the consumer's first next()
        self._validate_request(req)
        self._check_overrides(req)
        if self.engine.running:
            return self._stream_background(req)
        return self._stream_foreground(req)

    def _stream_foreground(self, req: GenerateRequest
                           ) -> Iterator[TrajectoryEvent]:
        events: List[TrajectoryEvent] = []

        def on_event(token: int, age: Optional[float]) -> None:
            events.append(TrajectoryEvent(index=len(events), token=token,
                                          age=age))

        er = self._build_engine_request(req, on_event=on_event)
        self.engine.submit(er)
        drained = 0
        while not er.done:
            progressed = self.engine.step()
            while drained < len(events):
                yield events[drained]
                drained += 1
            if not progressed and not er.done:
                raise RuntimeError("engine made no progress on an "
                                   "unfinished streaming request")
        while drained < len(events):
            yield events[drained]
            drained += 1

    def _stream_background(self, req: GenerateRequest
                           ) -> Iterator[TrajectoryEvent]:
        """Per-event streaming off a background-ticking engine: the loop
        thread pushes events through a queue as its tick sync lands."""
        import queue
        from repro.api.errors import RequestTimeoutError
        q: "queue.Queue" = queue.Queue()
        n_seen = [0]

        def on_event(token: int, age: Optional[float]) -> None:
            q.put(("event", TrajectoryEvent(index=n_seen[0], token=token,
                                            age=age)))
            n_seen[0] += 1

        def on_done(er: "EngineRequest") -> None:
            q.put(("done", er))

        er = self._build_engine_request(req, on_event=on_event,
                                        on_done=on_done)
        self.engine.submit(er)
        while True:
            try:
                kind, payload = q.get(timeout=self.request_timeout)
            except queue.Empty:
                raise RequestTimeoutError(
                    f"engine produced no event within "
                    f"{self.request_timeout}s") from None
            if kind == "event":
                yield payload
            else:
                if payload.error is not None:
                    raise payload.error
                return


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------
class Client:
    """Unified inference client: ``generate`` / ``generate_batch`` /
    ``stream`` / ``risk`` over a pluggable backend.

    >>> client = Client.from_artifact("/path/to/artifact")   # FAIR client
    >>> client = Client.from_params(params, cfg)             # in-process
    >>> client = Client.serving(params, cfg, slots=8)        # batched engine
    >>> client = Client.connect("http://host:8478")          # over the wire
    """

    def __init__(self, backend: InferenceBackend):
        self.backend = backend

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_artifact(cls, artifact_dir: str, **kw) -> "Client":
        return cls(ArtifactBackend(artifact_dir, **kw))

    @classmethod
    def from_params(cls, params, cfg: ModelConfig, **kw) -> "Client":
        return cls(LocalBackend(params, cfg, **kw))

    @classmethod
    def from_engine(cls, engine: BatchedEngine) -> "Client":
        return cls(EngineBackend(engine))

    @classmethod
    def serving(cls, params, cfg: ModelConfig, **engine_kwargs) -> "Client":
        return cls(EngineBackend.create(params, cfg, **engine_kwargs))

    @classmethod
    def connect(cls, url: str, **kw) -> "Client":
        """The fourth backend: a ``repro.serve.server`` across the network."""
        from repro.api.remote import RemoteBackend
        return cls(RemoteBackend(url, **kw))

    @staticmethod
    def backends() -> dict:
        """Registered backend name -> class (artifact/engine/local/remote)."""
        return dict(InferenceBackend.registry)

    # -- request plumbing ----------------------------------------------------
    @staticmethod
    def _req(req: Optional[GenerateRequest], kw) -> GenerateRequest:
        if req is None:
            return GenerateRequest(**kw)
        if kw:
            raise TypeError("pass either a GenerateRequest or keyword "
                            "arguments, not both")
        return req

    # -- entry points --------------------------------------------------------
    def generate(self, req: Optional[GenerateRequest] = None,
                 **kw) -> TrajectoryResult:
        return self.backend.generate(self._req(req, kw))

    def generate_batch(self, reqs: Sequence[GenerateRequest]
                       ) -> List[TrajectoryResult]:
        return self.backend.generate_batch(list(reqs))

    def stream(self, req: Optional[GenerateRequest] = None,
               **kw) -> Iterator[TrajectoryEvent]:
        return self.backend.stream(self._req(req, kw))

    def risk(self, tokens: Sequence[int],
             ages: Optional[Sequence[float]] = None, *,
             horizon: float = 5.0, top: int = 10) -> RiskReport:
        """Closed-form within-horizon next-event risks, highest first.

        P(next = i, t <= h) = softmax(logits)_i * (1 - e^{-Lambda h}).
        """
        return self.backend.risk(tokens, ages, horizon=horizon, top=top)

    def sample_futures(self, req: Optional[FuturesRequest] = None,
                       **kw) -> FuturesResult:
        """N Monte-Carlo continuations of one patient history, aggregated
        into a within-horizon ``RiskReport`` (plus the trajectories behind
        it).  Engine-backed clients fan the futures out through
        prefix-shared ``fork`` slots — ~1 prefill + ~1 prefix's KV for N
        futures; other backends fall back to vectorized (local) or
        sequential (artifact) generation.

        >>> client.sample_futures(tokens=[...], ages=[...], n_futures=32)
        """
        if req is None:
            req = FuturesRequest(**kw)
        elif kw:
            raise TypeError("pass either a FuturesRequest or keyword "
                            "arguments, not both")
        return self.backend.sample_futures(req)

    def cancel(self, request_id: str) -> bool:
        """Cancel an in-flight request by the ``request_id`` it was
        submitted with (set ``GenerateRequest.request_id`` yourself so you
        hold the handle).  Engine-backed and remote clients propagate this
        to slot eviction; returns False when nothing was cancelled."""
        return self.backend.cancel(request_id)
