"""Shared request/result schemas for every inference surface.

One vocabulary of dataclasses used by all four ``repro.api`` backends (and by
the ``InferenceSession`` compatibility shim), replacing the divergent
input/result conventions that grew around ``sdk.session``, ``serve.engine``
and ``core.sampler``.  Pure data — no JAX, no model imports — so schemas can
cross any process/serialization boundary the same way the artifact does.

Wire protocol (v1)
------------------
Every schema has a canonical JSON form (``to_json`` / ``from_json``) — the
contract ``repro.serve.server`` and ``repro.api.RemoteBackend`` speak, and
the shape a hand-written client (the paper's thin JS SDK) would produce:

* requests carry ``"protocol_version"`` (:data:`WIRE_PROTOCOL_VERSION`);
  ``from_json`` rejects a different major version with a structured
  ``protocol_version_mismatch`` error instead of mis-parsing;
* numpy arrays (``uniforms``) encode as
  ``{"shape": [...], "dtype": "float32", "b64": <base64 little-endian raw
  bytes>}`` — bit-exact across the wire; ``from_json`` also accepts plain
  nested lists for hand-written clients;
* ``rng`` is live host PRNG state and is *rejected* at serialization time
  (``rng_not_serializable``) — inject ``uniforms`` or pass ``seed`` for
  cross-process determinism.
"""
from __future__ import annotations

import base64
import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.api.errors import (InvalidRequestError, ProtocolVersionError,
                              RngNotSerializableError)

#: Major version of the JSON wire contract.  Bump ONLY on breaking schema
#: changes; additive fields are minor and do not bump this.
WIRE_PROTOCOL_VERSION = "1"


def _encode_array(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    if a.dtype.byteorder == ">":              # wire order is little-endian
        a = a.astype(a.dtype.newbyteorder("<"))
    return {"shape": list(a.shape), "dtype": str(a.dtype),
            "b64": base64.b64encode(a.tobytes()).decode("ascii")}


def _decode_array(obj, field: str) -> np.ndarray:
    if isinstance(obj, list):                 # hand-written-client form
        return np.asarray(obj, np.float32)
    if not isinstance(obj, dict) or "b64" not in obj:
        raise InvalidRequestError(
            f"{field}: expected base64 array object or nested lists")
    try:
        raw = base64.b64decode(obj["b64"])
        a = np.frombuffer(raw, dtype=np.dtype(obj.get("dtype", "float32")))
        return a.reshape(obj["shape"]).copy()
    except (ValueError, TypeError, KeyError) as e:
        raise InvalidRequestError(f"{field}: undecodable array ({e})") from e


def check_protocol(d: dict) -> None:
    """Refuse a body from a different wire-protocol major version (absent
    version is tolerated for hand-written minimal clients)."""
    v = d.get("protocol_version") if isinstance(d, dict) else None
    if v is not None and str(v) != WIRE_PROTOCOL_VERSION:
        raise ProtocolVersionError(
            f"wire protocol {v!r} != supported {WIRE_PROTOCOL_VERSION!r}")


def _require(d: dict, field: str):
    if field not in d:
        raise InvalidRequestError(f"missing required field {field!r}")
    return d[field]


@dataclasses.dataclass
class GenerateRequest:
    """One trajectory-generation request, backend-agnostic.

    ``tokens``/``ages`` are the patient's known history (ages omitted for
    generic-LM configs).  ``max_age``/``death_token`` of ``None`` defer to the
    backend's defaults (the artifact manifest's sampling block, or the model
    config).  ``uniforms`` — optional pre-drawn (max_new, V) U(0,1), row i
    consumed by the i-th sampled event — makes generation deterministic and
    bit-comparable across backends (claims C2/C3); otherwise draws come from
    ``rng`` (host backends) or a PRNGKey derived from ``seed``.
    """
    tokens: Sequence[int]
    ages: Optional[Sequence[float]] = None
    max_new: int = 64
    max_age: Optional[float] = None
    death_token: Optional[int] = None
    uniforms: Optional[np.ndarray] = None
    seed: int = 0
    # repro-lint: disable=RL004 rng is host-only by design: to_json rejects
    # it (RngNotSerializableError) and from_json can never reconstruct live
    # PRNG state, so it intentionally does not round-trip
    rng: Optional[np.random.Generator] = None
    # client-chosen handle for mid-flight cancellation (``Client.cancel`` /
    # ``POST /v1/cancel``); additive wire field, omitted when unset
    request_id: Optional[str] = None

    def to_json(self) -> dict:
        """Canonical wire form.  ``rng`` cannot cross a process boundary —
        inject ``uniforms`` (bit-exact) or pass ``seed`` instead."""
        if self.rng is not None:
            raise RngNotSerializableError(
                "GenerateRequest.rng holds live host PRNG state and is not "
                "JSON-serializable: inject `uniforms` for bit-exact "
                "cross-process determinism, or pass `seed`")
        d: dict = {
            "protocol_version": WIRE_PROTOCOL_VERSION,
            "tokens": [int(t) for t in self.tokens],
            "max_new": int(self.max_new),
            "seed": int(self.seed),
        }
        if self.ages is not None:
            d["ages"] = [float(a) for a in self.ages]
        if self.max_age is not None:
            d["max_age"] = float(self.max_age)
        if self.death_token is not None:
            d["death_token"] = int(self.death_token)
        if self.uniforms is not None:
            d["uniforms"] = _encode_array(np.asarray(self.uniforms))
        if self.request_id is not None:
            d["request_id"] = str(self.request_id)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "GenerateRequest":
        if not isinstance(d, dict):
            raise InvalidRequestError("request body must be a JSON object")
        check_protocol(d)
        u = d.get("uniforms")
        tokens = _require(d, "tokens")
        try:
            return cls(
                tokens=[int(t) for t in tokens],
                ages=([float(a) for a in d["ages"]]
                      if d.get("ages") is not None else None),
                max_new=int(d.get("max_new", 64)),
                max_age=(float(d["max_age"])
                         if d.get("max_age") is not None else None),
                death_token=(int(d["death_token"])
                             if d.get("death_token") is not None else None),
                uniforms=(_decode_array(u, "uniforms")
                          if u is not None else None),
                seed=int(d.get("seed", 0)),
                request_id=(str(d["request_id"])
                            if d.get("request_id") is not None else None))
        except InvalidRequestError:
            raise
        except (ValueError, TypeError) as e:    # wrong-typed field -> 400,
            raise InvalidRequestError(          # not a 500 internal
                f"malformed request field: {e}") from e


@dataclasses.dataclass
class TrajectoryEvent:
    """One generated event, as yielded by ``Client.stream``."""
    index: int                      # 0-based position in the generated suffix
    token: int
    age: Optional[float] = None     # None for generic-LM configs

    def to_json(self) -> dict:
        d: dict = {"index": int(self.index), "token": int(self.token)}
        if self.age is not None:
            d["age"] = float(self.age)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "TrajectoryEvent":
        return cls(index=int(_require(d, "index")),
                   token=int(_require(d, "token")),
                   age=(float(d["age"]) if d.get("age") is not None else None))


@dataclasses.dataclass
class TrajectoryResult:
    """Generated continuation of one trajectory (all backends).

    ``request_id`` echoes the id the request was tracked under when one was
    in play — client-supplied, or assigned by the multi-replica router,
    which pins ``stream``/``cancel``/``futures`` for that id to one replica.
    Additive wire field; omitted when unset.
    """
    tokens: List[int]
    ages: List[float]
    prompt_tokens: List[int]
    prompt_ages: List[float]
    backend: str = ""
    request_id: Optional[str] = None

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    @property
    def full_tokens(self) -> List[int]:
        return list(self.prompt_tokens) + list(self.tokens)

    @property
    def full_ages(self) -> List[float]:
        return list(self.prompt_ages) + list(self.ages)

    def events(self) -> List[TrajectoryEvent]:
        ages: List[Optional[float]] = (list(self.ages) if self.ages
                                       else [None] * len(self.tokens))
        return [TrajectoryEvent(index=i, token=t, age=a)
                for i, (t, a) in enumerate(zip(self.tokens, ages))]

    def to_json(self) -> dict:
        d: dict = {
            "protocol_version": WIRE_PROTOCOL_VERSION,
            "tokens": [int(t) for t in self.tokens],
            "ages": [float(a) for a in self.ages],
            "prompt_tokens": [int(t) for t in self.prompt_tokens],
            "prompt_ages": [float(a) for a in self.prompt_ages],
            "backend": self.backend,
        }
        if self.request_id is not None:
            d["request_id"] = str(self.request_id)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "TrajectoryResult":
        check_protocol(d)
        return cls(tokens=[int(t) for t in _require(d, "tokens")],
                   ages=[float(a) for a in d.get("ages", [])],
                   prompt_tokens=[int(t) for t in d.get("prompt_tokens", [])],
                   prompt_ages=[float(a) for a in d.get("prompt_ages", [])],
                   backend=str(d.get("backend", "")),
                   request_id=(str(d["request_id"])
                               if d.get("request_id") is not None else None))


@dataclasses.dataclass
class FuturesRequest:
    """N Monte-Carlo futures of one patient history — the morbidity-risk
    workload (``Client.sample_futures`` / ``POST /v1/futures``).

    ``uniforms`` — optional pre-drawn (n_futures, max_new, V) U(0,1), row
    ``[i, j]`` consumed by future ``i``'s ``j``-th sampled event — makes
    the whole fan-out deterministic and bit-comparable across backends;
    otherwise draws derive from ``seed``.  ``horizon``/``top`` shape the
    aggregated ``RiskReport``.  On engine-backed servers ``request_id``
    prefixes the forked children's ids (``<id>/fork-<i>``), so individual
    futures can be cancelled mid-flight."""
    tokens: Sequence[int]
    ages: Optional[Sequence[float]] = None
    n_futures: int = 16
    max_new: int = 48
    horizon: float = 5.0
    top: int = 10
    uniforms: Optional[np.ndarray] = None
    seed: int = 0
    request_id: Optional[str] = None

    def to_json(self) -> dict:
        d: dict = {
            "protocol_version": WIRE_PROTOCOL_VERSION,
            "tokens": [int(t) for t in self.tokens],
            "n_futures": int(self.n_futures),
            "max_new": int(self.max_new),
            "horizon": float(self.horizon),
            "top": int(self.top),
            "seed": int(self.seed),
        }
        if self.ages is not None:
            d["ages"] = [float(a) for a in self.ages]
        if self.uniforms is not None:
            d["uniforms"] = _encode_array(np.asarray(self.uniforms))
        if self.request_id is not None:
            d["request_id"] = str(self.request_id)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "FuturesRequest":
        if not isinstance(d, dict):
            raise InvalidRequestError("futures body must be a JSON object")
        check_protocol(d)
        u = d.get("uniforms")
        tokens = _require(d, "tokens")
        try:
            return cls(
                tokens=[int(t) for t in tokens],
                ages=([float(a) for a in d["ages"]]
                      if d.get("ages") is not None else None),
                n_futures=int(d.get("n_futures", 16)),
                max_new=int(d.get("max_new", 48)),
                horizon=float(d.get("horizon", 5.0)),
                top=int(d.get("top", 10)),
                uniforms=(_decode_array(u, "uniforms")
                          if u is not None else None),
                seed=int(d.get("seed", 0)),
                request_id=(str(d["request_id"])
                            if d.get("request_id") is not None else None))
        except InvalidRequestError:
            raise
        except (ValueError, TypeError) as e:
            raise InvalidRequestError(
                f"malformed futures request field: {e}") from e


@dataclasses.dataclass
class RiskItem:
    token: int
    risk: float

    def to_json(self) -> dict:
        return {"token": int(self.token), "risk": float(self.risk)}

    @classmethod
    def from_json(cls, d: dict) -> "RiskItem":
        return cls(token=int(_require(d, "token")),
                   risk=float(_require(d, "risk")))


@dataclasses.dataclass
class RiskReport:
    """Within-horizon next-event risks, highest first (the App's output)."""
    horizon: float
    items: List[RiskItem]
    backend: str = ""

    def top(self, n: int) -> List[RiskItem]:
        return self.items[:n]

    def as_dicts(self) -> List[dict]:
        """Legacy ``InferenceSession.estimate_risk`` schema."""
        return [{"token": it.token, "risk": it.risk} for it in self.items]

    def to_json(self) -> dict:
        return {
            "protocol_version": WIRE_PROTOCOL_VERSION,
            "horizon": float(self.horizon),
            "items": [it.to_json() for it in self.items],
            "backend": self.backend,
        }

    @classmethod
    def from_json(cls, d: dict) -> "RiskReport":
        check_protocol(d)
        return cls(horizon=float(_require(d, "horizon")),
                   items=[RiskItem.from_json(it)
                          for it in d.get("items", [])],
                   backend=str(d.get("backend", "")))


@dataclasses.dataclass
class FuturesResult:
    """Aggregated Monte-Carlo futures: the within-horizon ``RiskReport``
    plus the N sampled continuations behind it (each a
    ``TrajectoryResult``, so parity against any other backend is
    assertable event for event).  ``sharing`` carries the serving engine's
    pool telemetry snapshotted at completion — engine-LIFETIME cumulative
    counters (forks, copy-on-write copies, preemptions, prefix-cache hit
    rate since engine start), not per-request deltas — and is empty for
    host-loop backends."""
    risk: RiskReport
    trajectories: List[TrajectoryResult]
    n_futures: int
    backend: str = ""
    sharing: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {
            "protocol_version": WIRE_PROTOCOL_VERSION,
            "risk": self.risk.to_json(),
            "trajectories": [t.to_json() for t in self.trajectories],
            "n_futures": int(self.n_futures),
            "backend": self.backend,
            "sharing": self.sharing,
        }

    @classmethod
    def from_json(cls, d: dict) -> "FuturesResult":
        check_protocol(d)
        return cls(risk=RiskReport.from_json(_require(d, "risk")),
                   trajectories=[TrajectoryResult.from_json(t)
                                 for t in d.get("trajectories", [])],
                   n_futures=int(d.get("n_futures", 0)),
                   backend=str(d.get("backend", "")),
                   sharing=dict(d.get("sharing") or {}))
