"""Shared request/result schemas for every inference surface.

One vocabulary of dataclasses used by all three ``repro.api`` backends (and by
the ``InferenceSession`` compatibility shim), replacing the three divergent
input/result conventions that grew around ``sdk.session``, ``serve.engine``
and ``core.sampler``.  Pure data — no JAX, no model imports — so schemas can
cross any process/serialization boundary the same way the artifact does.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class GenerateRequest:
    """One trajectory-generation request, backend-agnostic.

    ``tokens``/``ages`` are the patient's known history (ages omitted for
    generic-LM configs).  ``max_age``/``death_token`` of ``None`` defer to the
    backend's defaults (the artifact manifest's sampling block, or the model
    config).  ``uniforms`` — optional pre-drawn (max_new, V) U(0,1), row i
    consumed by the i-th sampled event — makes generation deterministic and
    bit-comparable across backends (claims C2/C3); otherwise draws come from
    ``rng`` (host backends) or a PRNGKey derived from ``seed``.
    """
    tokens: Sequence[int]
    ages: Optional[Sequence[float]] = None
    max_new: int = 64
    max_age: Optional[float] = None
    death_token: Optional[int] = None
    uniforms: Optional[np.ndarray] = None
    seed: int = 0
    rng: Optional[np.random.Generator] = None


@dataclasses.dataclass
class TrajectoryEvent:
    """One generated event, as yielded by ``Client.stream``."""
    index: int                      # 0-based position in the generated suffix
    token: int
    age: Optional[float] = None     # None for generic-LM configs


@dataclasses.dataclass
class TrajectoryResult:
    """Generated continuation of one trajectory (all backends)."""
    tokens: List[int]
    ages: List[float]
    prompt_tokens: List[int]
    prompt_ages: List[float]
    backend: str = ""

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    @property
    def full_tokens(self) -> List[int]:
        return list(self.prompt_tokens) + list(self.tokens)

    @property
    def full_ages(self) -> List[float]:
        return list(self.prompt_ages) + list(self.ages)

    def events(self) -> List[TrajectoryEvent]:
        ages: List[Optional[float]] = (list(self.ages) if self.ages
                                       else [None] * len(self.tokens))
        return [TrajectoryEvent(index=i, token=t, age=a)
                for i, (t, a) in enumerate(zip(self.tokens, ages))]


@dataclasses.dataclass
class RiskItem:
    token: int
    risk: float


@dataclasses.dataclass
class RiskReport:
    """Within-horizon next-event risks, highest first (the App's output)."""
    horizon: float
    items: List[RiskItem]
    backend: str = ""

    def top(self, n: int) -> List[RiskItem]:
        return self.items[:n]

    def as_dicts(self) -> List[dict]:
        """Legacy ``InferenceSession.estimate_risk`` schema."""
        return [{"token": it.token, "risk": it.risk} for it in self.items]
