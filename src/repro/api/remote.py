"""RemoteBackend: the network as a fourth pluggable inference backend.

Implements the ``InferenceBackend`` surface over the versioned JSON/SSE wire
protocol served by ``repro.serve.server`` — stdlib ``urllib`` only, no
model code, no JAX — so ``Client(RemoteBackend(url))`` (or
``Client.connect(url)``) is a drop-in for the artifact/engine/local backends
and bit-identical to them under injected uniforms (the uniforms cross the
wire as raw little-endian bytes, and tokens/ages round-trip exactly through
JSON numbers).

The server is the source of truth for validation: a bad request comes back
as ``{"error": {"code", "message"}}`` and is re-raised here as the *same*
typed ``repro.api.errors.ApiError`` subclass an in-process backend would
have raised, so error handling is backend-agnostic too.

Results keep the serving backend visible: ``result.backend`` is
``"remote[engine]"`` etc., recording both the hop and what answered.
"""
from __future__ import annotations

import json
import urllib.error
import urllib.request
from typing import Iterator, List, Optional, Sequence

from repro.api.client import InferenceBackend
from repro.api.errors import (ApiError, InternalServerError,
                              ProtocolVersionError, error_from_json)
from repro.api.schemas import (WIRE_PROTOCOL_VERSION, GenerateRequest,
                               RiskReport, TrajectoryEvent, TrajectoryResult)

__all__ = ["RemoteBackend"]


class RemoteBackend(InferenceBackend):
    """Client half of the wire protocol (see ``repro.serve.server``)."""
    name = "remote"

    def __init__(self, url: str, *, timeout: float = 300.0):
        self.url = url.rstrip("/")
        self.timeout = timeout
        m = self._request("GET", "/v1/manifest")
        v = str(m.get("protocol_version"))
        if v != WIRE_PROTOCOL_VERSION:
            raise ProtocolVersionError(
                f"server at {self.url} speaks wire protocol {v!r}; this "
                f"client supports {WIRE_PROTOCOL_VERSION!r}")
        self.server_manifest = m
        self.remote_backend = str(m.get("backend", "?"))
        mm = m.get("model", {})
        self.seq_len = int(mm["seq_len"])
        self.vocab_size = int(mm["vocab_size"])
        self.has_ages = bool(mm["has_ages"])
        self.max_age = float(mm["max_age"])
        self.death_token = int(mm["death_token"])

    # -- wire plumbing -------------------------------------------------------
    def _request(self, method: str, path: str, payload: Optional[dict] = None,
                 stream: bool = False):
        data = (json.dumps(payload).encode("utf-8")
                if payload is not None else None)
        req = urllib.request.Request(
            self.url + path, data=data, method=method,
            headers={"Content-Type": "application/json",
                     "Accept": ("text/event-stream" if stream
                                else "application/json")})
        try:
            resp = urllib.request.urlopen(req, timeout=self.timeout)
        except urllib.error.HTTPError as e:
            body = e.read()
            try:
                raise error_from_json(json.loads(body.decode("utf-8")))
            except (json.JSONDecodeError, UnicodeDecodeError):
                raise InternalServerError(
                    f"HTTP {e.code} from {self.url}{path}: "
                    f"{body[:200]!r}") from None
        except urllib.error.URLError as e:
            raise InternalServerError(
                f"cannot reach {self.url}{path}: {e.reason}") from None
        if stream:
            return resp
        with resp:
            return json.loads(resp.read().decode("utf-8"))

    def _relabel(self, obj):
        obj.backend = f"{self.name}[{obj.backend or self.remote_backend}]"
        return obj

    # -- InferenceBackend surface --------------------------------------------
    def generate(self, req: GenerateRequest) -> TrajectoryResult:
        out = self._request("POST", "/v1/generate", req.to_json())
        return self._relabel(TrajectoryResult.from_json(out))

    def generate_batch(self, reqs: Sequence[GenerateRequest]
                       ) -> List[TrajectoryResult]:
        out = self._request("POST", "/v1/generate_batch",
                            {"protocol_version": WIRE_PROTOCOL_VERSION,
                             "requests": [r.to_json() for r in reqs]})
        return [self._relabel(TrajectoryResult.from_json(r))
                for r in out.get("results", [])]

    def stream(self, req: GenerateRequest) -> Iterator[TrajectoryEvent]:
        """Per-event SSE: frames yield as the server's engine tick lands.

        Non-generator wrapper: serialization (``rng``) and server-side
        validation errors raise HERE, at the call — the same eager contract
        as the in-process backends."""
        resp = self._request("POST", "/v1/stream", req.to_json(), stream=True)
        return self._parse_sse(resp)

    def _parse_sse(self, resp) -> Iterator[TrajectoryEvent]:
        try:
            event: Optional[str] = None
            data_lines: List[str] = []
            for raw in resp:
                line = raw.decode("utf-8").rstrip("\r\n")
                if line.startswith("event:"):
                    event = line[len("event:"):].strip()
                elif line.startswith("data:"):
                    data_lines.append(line[len("data:"):].strip())
                elif line == "" and event is not None:
                    payload = json.loads("\n".join(data_lines) or "null")
                    if event == "event":
                        yield TrajectoryEvent.from_json(payload)
                    elif event == "error":
                        raise error_from_json(payload)
                    elif event == "done":
                        return
                    event, data_lines = None, []
            raise InternalServerError(
                "SSE stream ended without a 'done' frame")
        finally:
            resp.close()

    def risk(self, tokens: Sequence[int],
             ages: Optional[Sequence[float]] = None, *,
             horizon: float = 5.0, top: int = 10) -> RiskReport:
        payload: dict = {"protocol_version": WIRE_PROTOCOL_VERSION,
                         "tokens": [int(t) for t in tokens],
                         "horizon": float(horizon), "top": int(top)}
        if ages is not None:
            payload["ages"] = [float(a) for a in ages]
        out = self._request("POST", "/v1/risk", payload)
        return self._relabel(RiskReport.from_json(out))

    def logits(self, tokens, ages=None):
        raise NotImplementedError(
            "the wire protocol exposes risk(), not raw logits — the paper's "
            "privacy boundary keeps bulk logit export off the service "
            "surface; use risk() or an in-process backend")

    def healthz(self) -> dict:
        return self._request("GET", "/v1/healthz")
