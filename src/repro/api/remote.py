"""RemoteBackend: the network as a fourth pluggable inference backend.

Implements the ``InferenceBackend`` surface over the versioned JSON/SSE wire
protocol served by ``repro.serve.server`` — stdlib ``http.client`` only, no
model code, no JAX — so ``Client(RemoteBackend(url))`` (or
``Client.connect(url)``) is a drop-in for the artifact/engine/local backends
and bit-identical to them under injected uniforms (the uniforms cross the
wire as raw little-endian bytes, and tokens/ages round-trip exactly through
JSON numbers).

Connection policy: the server speaks HTTP/1.1 with keep-alive, so this
backend holds **one persistent connection** and pipelines sequential JSON
calls over it instead of paying a TCP handshake per request (the req/s
delta is measured by ``benchmarks/run.py http``; pass ``keep_alive=False``
to get the old socket-per-call behaviour).  A stale pooled socket (server
restarted, idle timeout) is retried once on a fresh connection.  SSE
streams are close-delimited and always use a dedicated connection.

The server is the source of truth for validation: a bad request comes back
as ``{"error": {"code", "message"}}`` and is re-raised here as the *same*
typed ``repro.api.errors.ApiError`` subclass an in-process backend would
have raised, so error handling is backend-agnostic too.  Cancellation
(``cancel(request_id)`` -> ``POST /v1/cancel``) propagates to engine slot
eviction server-side; a stream cancelled mid-flight terminates with a
``cancelled`` frame, surfaced as ``RequestCancelledError``.

Results keep the serving backend visible: ``result.backend`` is
``"remote[engine]"`` etc., recording both the hop and what answered.
"""
from __future__ import annotations

import http.client
import json
import threading
from typing import Iterator, List, Optional, Sequence, Tuple
from urllib.parse import urlsplit

from repro.api.client import InferenceBackend
from repro.api.errors import (InternalServerError, ProtocolVersionError,
                              ReplicaUnavailableError, error_from_json)
from repro.api.schemas import (WIRE_PROTOCOL_VERSION, FuturesRequest,
                               FuturesResult, GenerateRequest, RiskReport,
                               TrajectoryEvent, TrajectoryResult)

__all__ = ["RemoteBackend"]


class RemoteBackend(InferenceBackend):
    """Client half of the wire protocol (see ``repro.serve.server``)."""
    name = "remote"

    def __init__(self, url: str, *, timeout: float = 300.0,
                 connect_timeout: Optional[float] = None,
                 read_timeout: Optional[float] = None,
                 keep_alive: bool = True):
        self.url = url.rstrip("/")
        sp = urlsplit(self.url if "//" in self.url else "http://" + self.url)
        if sp.scheme not in ("http", ""):
            raise ValueError(f"RemoteBackend speaks plain http, not "
                             f"{sp.scheme!r}")
        self._host = sp.hostname or "127.0.0.1"
        self._port = sp.port or 80
        self._base_path = sp.path.rstrip("/")
        # `timeout` is the one-knob form; the split knobs let a router
        # health probe fail fast on a dead replica (small connect_timeout)
        # while long generate calls keep their full read budget
        self.timeout = timeout
        self.connect_timeout = (timeout if connect_timeout is None
                                else connect_timeout)
        self.read_timeout = timeout if read_timeout is None else read_timeout
        self.keep_alive = keep_alive
        self._conn: Optional[http.client.HTTPConnection] = None
        self._conn_lock = threading.Lock()
        #: sockets dialed so far — the keep-alive benchmark/tests assert
        #: this stays at 1 across sequential JSON calls
        self.connections_opened = 0
        try:
            m = self._request("GET", "/v1/manifest")
            v = str(m.get("protocol_version"))
            if v != WIRE_PROTOCOL_VERSION:
                raise ProtocolVersionError(
                    f"server at {self.url} speaks wire protocol {v!r}; this "
                    f"client supports {WIRE_PROTOCOL_VERSION!r}")
        except BaseException:
            # a failed handshake raises out of __init__: the caller never
            # gets the instance, so the pooled socket must not outlive it
            self.close()
            raise
        self.server_manifest = m
        self.remote_backend = str(m.get("backend", "?"))
        mm = m.get("model", {})
        self.seq_len = int(mm["seq_len"])
        self.vocab_size = int(mm["vocab_size"])
        self.has_ages = bool(mm["has_ages"])
        self.max_age = float(mm["max_age"])
        self.death_token = int(mm["death_token"])

    # -- wire plumbing -------------------------------------------------------
    def _open(self) -> http.client.HTTPConnection:
        """Dial under ``connect_timeout``, then rebudget the established
        socket to ``read_timeout`` — raises ``OSError`` on dial failure
        (callers map it to the transport-level ``replica_unavailable``)."""
        self.connections_opened += 1
        conn = http.client.HTTPConnection(self._host, self._port,
                                          timeout=self.connect_timeout)
        try:
            conn.connect()
            if conn.sock is not None:
                conn.sock.settimeout(self.read_timeout)
        except BaseException:
            conn.close()
            raise
        return conn

    def _roundtrip(self, conn, method: str, path: str, body, stream: bool):
        conn.request(method, self._base_path + path, body=body, headers={
            "Content-Type": "application/json",
            "Accept": "text/event-stream" if stream else "application/json"})
        return conn.getresponse()

    def _raise_http(self, status: int, path: str, raw: bytes):
        try:
            err = error_from_json(json.loads(raw.decode("utf-8")))
        except (json.JSONDecodeError, UnicodeDecodeError):
            err = InternalServerError(
                f"HTTP {status} from {self.url}{path}: {raw[:200]!r}")
        raise err

    def _request(self, method: str, path: str, payload: Optional[dict] = None,
                 stream: bool = False, pooled: bool = True):
        body = (json.dumps(payload).encode("utf-8")
                if payload is not None else None)
        if stream or not pooled or not self.keep_alive:
            # dedicated socket: SSE holds its response open until the
            # ``done`` frame, and /v1/cancel must not queue behind the
            # pooled connection's in-flight call (the one it cancels)
            try:
                conn = self._open()
            except OSError as e:
                raise ReplicaUnavailableError(
                    f"cannot reach {self.url}{path}: {e}") from None
            try:
                resp = self._roundtrip(conn, method, path, body, stream)
            except OSError as e:
                conn.close()
                raise ReplicaUnavailableError(
                    f"cannot reach {self.url}{path}: {e}") from None
            if stream:
                if resp.status >= 400:
                    raw = resp.read()
                    conn.close()
                    self._raise_http(resp.status, path, raw)
                return resp, conn
            raw = resp.read()
            conn.close()
        else:
            # A previously-used pooled socket may have been dropped by the
            # server between calls; ONLY that case is retried (once, on a
            # fresh connection).  Timeouts and failures on a fresh socket
            # are never retried — the server may already be executing a
            # non-idempotent request.
            _reuse_errors = (http.client.RemoteDisconnected,
                             ConnectionResetError, BrokenPipeError)
            with self._conn_lock:
                for attempt in (0, 1):
                    fresh = self._conn is None
                    try:
                        conn = self._conn if not fresh else self._open()
                    except OSError as e:
                        raise ReplicaUnavailableError(
                            f"cannot reach {self.url}{path}: {e}") from None
                    self._conn = conn
                    try:
                        resp = self._roundtrip(conn, method, path, body,
                                               stream=False)
                        raw = resp.read()
                    except (http.client.HTTPException, OSError) as e:
                        self._conn = None
                        conn.close()
                        if attempt == 0 and not fresh \
                                and isinstance(e, _reuse_errors):
                            continue          # stale keep-alive socket
                        raise ReplicaUnavailableError(
                            f"cannot reach {self.url}{path}: {e}") from None
                    if resp.will_close:       # server opted out of reuse
                        self._conn = None
                        conn.close()
                    break
        if resp.status >= 400:
            self._raise_http(resp.status, path, raw)
        return json.loads(raw.decode("utf-8"))

    def close(self) -> None:
        """Drop the pooled keep-alive connection (idempotent)."""
        with self._conn_lock:
            if self._conn is not None:
                self._conn.close()
                self._conn = None

    def _relabel(self, obj):
        obj.backend = f"{self.name}[{obj.backend or self.remote_backend}]"
        return obj

    # -- InferenceBackend surface --------------------------------------------
    def generate(self, req: GenerateRequest) -> TrajectoryResult:
        out = self._request("POST", "/v1/generate", req.to_json())
        return self._relabel(TrajectoryResult.from_json(out))

    def generate_batch(self, reqs: Sequence[GenerateRequest]
                       ) -> List[TrajectoryResult]:
        out = self._request("POST", "/v1/generate_batch",
                            {"protocol_version": WIRE_PROTOCOL_VERSION,
                             "requests": [r.to_json() for r in reqs]})
        return [self._relabel(TrajectoryResult.from_json(r))
                for r in out.get("results", [])]

    def stream(self, req: GenerateRequest) -> Iterator[TrajectoryEvent]:
        """Per-event SSE: frames yield as the server's engine tick lands.

        Non-generator wrapper: serialization (``rng``) and server-side
        validation errors raise HERE, at the call — the same eager contract
        as the in-process backends."""
        resp, conn = self._request("POST", "/v1/stream", req.to_json(),
                                   stream=True)
        return self._parse_sse(resp, conn)

    def _parse_sse(self, resp, conn) -> Iterator[TrajectoryEvent]:
        try:
            event: Optional[str] = None
            data_lines: List[str] = []
            try:
                for raw in resp:
                    line = raw.decode("utf-8").rstrip("\r\n")
                    if line.startswith("event:"):
                        event = line[len("event:"):].strip()
                    elif line.startswith("data:"):
                        data_lines.append(line[len("data:"):].strip())
                    elif line == "" and event is not None:
                        payload = json.loads("\n".join(data_lines) or "null")
                        if event == "event":
                            yield TrajectoryEvent.from_json(payload)
                        elif event in ("error", "cancelled"):
                            # `cancelled` is the terminal frame of
                            # /v1/cancel — reconstructed as
                            # RequestCancelledError by code
                            raise error_from_json(payload)
                        elif event == "done":
                            return
                        event, data_lines = None, []
            except (http.client.HTTPException, OSError) as e:
                raise ReplicaUnavailableError(
                    f"server at {self.url} went away mid-stream: "
                    f"{e}") from None
            # a clean close with no terminal frame is the same condition:
            # the server died between events (SSE is close-delimited)
            raise ReplicaUnavailableError(
                f"server at {self.url} closed the SSE stream without a "
                f"terminal frame")
        finally:
            resp.close()
            conn.close()

    def cancel(self, request_id: str) -> bool:
        """Server-side cancellation: ``POST /v1/cancel`` evicts the request
        from its engine slot (blocks freed) and waiters get the structured
        ``request_cancelled`` error / ``cancelled`` SSE frame.  Sent on a
        dedicated connection so it can overtake the pooled connection's
        in-flight call — usually exactly the one being cancelled."""
        out = self._request("POST", "/v1/cancel",
                            {"protocol_version": WIRE_PROTOCOL_VERSION,
                             "request_id": str(request_id)},
                            pooled=False)
        return bool(out.get("cancelled"))

    def sample_futures(self, req: FuturesRequest) -> FuturesResult:
        """Monte-Carlo futures over the wire (``POST /v1/futures``): the
        server fans the N continuations out through its backend — on an
        engine server, prefix-shared ``fork`` slots — and returns the
        aggregated ``RiskReport`` plus every trajectory, bit-identical to
        an in-process engine under injected uniforms (the uniforms cross
        as raw little-endian bytes)."""
        out = self._request("POST", "/v1/futures", req.to_json())
        res = FuturesResult.from_json(out)
        self._relabel(res)
        self._relabel(res.risk)
        for t in res.trajectories:
            self._relabel(t)
        return res

    def risk(self, tokens: Sequence[int],
             ages: Optional[Sequence[float]] = None, *,
             horizon: float = 5.0, top: int = 10) -> RiskReport:
        payload: dict = {"protocol_version": WIRE_PROTOCOL_VERSION,
                         "tokens": [int(t) for t in tokens],
                         "horizon": float(horizon), "top": int(top)}
        if ages is not None:
            payload["ages"] = [float(a) for a in ages]
        out = self._request("POST", "/v1/risk", payload)
        return self._relabel(RiskReport.from_json(out))

    def logits(self, tokens, ages=None):
        raise NotImplementedError(
            "the wire protocol exposes risk(), not raw logits — the paper's "
            "privacy boundary keeps bulk logit export off the service "
            "surface; use risk() or an in-process backend")

    def healthz(self) -> dict:
        return self._request("GET", "/v1/healthz")
