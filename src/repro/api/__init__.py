"""Unified inference client API (the paper's SDK surface, backend-pluggable).

``Client`` + three backends (artifact / engine / local) over shared request
and result schemas — see ``repro.api.client`` for the design notes.
"""
from repro.api.client import (ArtifactBackend, Client, EngineBackend,
                              InferenceBackend, LocalBackend)
from repro.api.schemas import (GenerateRequest, RiskItem, RiskReport,
                               TrajectoryEvent, TrajectoryResult)

__all__ = [
    "Client", "InferenceBackend",
    "ArtifactBackend", "EngineBackend", "LocalBackend",
    "GenerateRequest", "TrajectoryEvent", "TrajectoryResult",
    "RiskItem", "RiskReport",
]
