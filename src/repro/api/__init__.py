"""Unified inference client API (the paper's SDK surface, backend-pluggable).

``Client`` + four backends — artifact / engine / local in-process, plus
``RemoteBackend`` speaking the versioned JSON/SSE wire protocol against a
``repro.serve.server`` — over shared request/result schemas and one
structured error taxonomy.  See ``repro.api.client`` for the design notes.
"""
from repro.api.client import (ArtifactBackend, Client, EngineBackend,
                              InferenceBackend, LocalBackend)
from repro.api.errors import (AgesLengthMismatchError, AgesRequiredError,
                              ApiError, EmptyTrajectoryError,
                              ProtocolVersionError, ReplicaUnavailableError,
                              RequestCancelledError, RequestTimeoutError,
                              RngNotSerializableError, TooLongError,
                              error_from_code, error_from_json)
from repro.api.remote import RemoteBackend
from repro.api.schemas import (WIRE_PROTOCOL_VERSION, FuturesRequest,
                               FuturesResult, GenerateRequest, RiskItem,
                               RiskReport, TrajectoryEvent, TrajectoryResult)

__all__ = [
    "Client", "InferenceBackend",
    "ArtifactBackend", "EngineBackend", "LocalBackend", "RemoteBackend",
    "GenerateRequest", "TrajectoryEvent", "TrajectoryResult",
    "FuturesRequest", "FuturesResult",
    "RiskItem", "RiskReport", "WIRE_PROTOCOL_VERSION",
    "ApiError", "EmptyTrajectoryError", "TooLongError", "AgesRequiredError",
    "AgesLengthMismatchError", "RngNotSerializableError",
    "ProtocolVersionError", "RequestCancelledError", "RequestTimeoutError",
    "ReplicaUnavailableError", "error_from_code", "error_from_json",
]
