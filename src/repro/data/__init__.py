"""Data substrate: synthetic disease histories, vocab, batching."""
from repro.data.pipeline import (batches, dataset_stats, lm_batch,
                                 pack_trajectories)
from repro.data.synthetic import SimulatorConfig, generate_dataset

__all__ = ["batches", "dataset_stats", "lm_batch", "pack_trajectories",
           "SimulatorConfig", "generate_dataset"]
