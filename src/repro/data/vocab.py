"""Delphi-style event vocabulary: ICD-10-chapter-structured disease codes.

Layout (matching the Delphi convention of specials + static + disease codes):
  0            PAD
  1            DEATH          (the termination token, paper default)
  2            NO_EVENT       (5-yearly "no event" marker, loss-masked)
  3..4         sex            (female / male)
  5..12        lifestyle      (BMI / smoking / alcohol tertiles-ish)
  13..1288     disease codes  (1276 codes across 26 ICD-10 chapters A..Z)

Total vocab = 1289 (``configs/delphi_2m.py``).
"""
from __future__ import annotations

from typing import List

PAD = 0
DEATH = 1
NO_EVENT = 2
SEX_FEMALE = 3
SEX_MALE = 4
LIFESTYLE0 = 5
N_LIFESTYLE = 8
DISEASE0 = 13
N_DISEASE = 1276
VOCAB_SIZE = DISEASE0 + N_DISEASE  # 1289

N_CHAPTERS = 26
_PER_CHAPTER = -(-N_DISEASE // N_CHAPTERS)


def chapter_of(code: int) -> int:
    """ICD-10 chapter index (0..25) of a disease code."""
    assert DISEASE0 <= code < VOCAB_SIZE
    return (code - DISEASE0) // _PER_CHAPTER


def code_name(code: int) -> str:
    """Human-readable ICD-ish label, e.g. 'C12.3' (used by the SDK display)."""
    if code == PAD:
        return "<pad>"
    if code == DEATH:
        return "Death"
    if code == NO_EVENT:
        return "No event"
    if code in (SEX_FEMALE, SEX_MALE):
        return "Sex:F" if code == SEX_FEMALE else "Sex:M"
    if LIFESTYLE0 <= code < DISEASE0:
        return f"Lifestyle:{code - LIFESTYLE0}"
    ch = chapter_of(code)
    within = (code - DISEASE0) % _PER_CHAPTER
    return f"{chr(ord('A') + ch)}{within // 10:02d}.{within % 10}"


def all_names() -> List[str]:
    return [code_name(c) for c in range(VOCAB_SIZE)]
