"""Synthetic disease-history simulator (the released-data stand-in).

The paper trains on the 7,144-patient synthetic subset released with Delphi;
we reproduce the *generating process family*: an age-dependent competing-risk
model with comorbidity coupling —

  * per-code Gompertz hazard  lambda_i(age) = exp(a_i + b_i * age/10)
  * comorbidity boosts: each code has a few partner codes whose prior
    occurrence adds to its log-hazard
  * mortality hazard grows with age and with accumulated morbidity burden
  * "no event" marker tokens every 5 event-free years (as in Delphi), which
    doubles as hazard-refresh thinning for the piecewise-constant
    approximation of the Gompertz clock
  * diseases are first-occurrence (chronic): a code fires at most once

Output trajectories are (tokens, ages) sequences starting with a sex token at
age 0, terminated by DEATH or censored at ``max_age``.  Fully deterministic
given the seed; defaults produce the paper's 7,144 + 7,144 split.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.data import vocab as V


@dataclasses.dataclass
class SimulatorConfig:
    n_train: int = 7144
    n_val: int = 7144
    seed: int = 0
    max_age: float = 85.0
    no_event_interval: float = 5.0
    mean_log_hazard: float = -10.4
    sd_log_hazard: float = 1.0
    mean_age_slope: float = 0.35     # per decade
    sd_age_slope: float = 0.15
    n_partners: int = 5
    partner_boost: float = 0.4
    death_base: float = -10.3
    death_age_slope: float = 0.9     # per decade (Gompertz mortality)
    death_morbidity_boost: float = 0.04
    max_events: int = 120


def _hazard_params(rng: np.random.Generator, cfg: SimulatorConfig):
    n = V.N_DISEASE
    a = rng.normal(cfg.mean_log_hazard, cfg.sd_log_hazard, n)
    b = np.clip(rng.normal(cfg.mean_age_slope, cfg.sd_age_slope, n), 0.0, None)
    partners = rng.integers(0, n, (n, cfg.n_partners))
    boosts = rng.uniform(0.2, 0.2 + cfg.partner_boost, (n, cfg.n_partners))
    return a, b, partners, boosts


def simulate_patient(rng: np.random.Generator, a, b, partners, boosts,
                     cfg: SimulatorConfig) -> Tuple[np.ndarray, np.ndarray]:
    tokens = [V.SEX_FEMALE if rng.random() < 0.5 else V.SEX_MALE]
    ages = [0.0]
    # one lifestyle token at age ~20 keeps the static-covariate pattern
    lifestyle_age = rng.uniform(18.0, 25.0)
    lifestyle_tok = V.LIFESTYLE0 + int(rng.integers(0, V.N_LIFESTYLE))

    age = 0.0
    occurred = np.zeros(V.N_DISEASE, bool)
    extra = np.zeros(V.N_DISEASE)          # comorbidity log-hazard boosts
    emitted_lifestyle = False

    def maybe_emit_lifestyle(new_age):
        # the static lifestyle token is emitted the moment age crosses its
        # recording age, BEFORE any event at new_age (keeps ages monotone)
        nonlocal emitted_lifestyle
        if not emitted_lifestyle and new_age >= lifestyle_age:
            tokens.append(lifestyle_tok)
            ages.append(lifestyle_age)
            emitted_lifestyle = True

    while len(tokens) < cfg.max_events:
        log_rates = a + b * (age / 10.0) + extra
        rates = np.where(occurred, 0.0, np.exp(log_rates))
        death_rate = np.exp(cfg.death_base + cfg.death_age_slope * (age / 10.0)
                            + cfg.death_morbidity_boost * occurred.sum())
        total = rates.sum() + death_rate
        dt = rng.exponential(1.0 / total)
        if dt > cfg.no_event_interval:
            # no event within the refresh window: emit marker, refresh hazards
            age += cfg.no_event_interval
            if age >= cfg.max_age:
                break
            maybe_emit_lifestyle(age)
            tokens.append(V.NO_EVENT)
            ages.append(age)
            continue
        age += dt
        if age >= cfg.max_age:
            break
        maybe_emit_lifestyle(age)
        if rng.random() < death_rate / total:
            tokens.append(V.DEATH)
            ages.append(age)
            break
        code = rng.choice(V.N_DISEASE, p=rates / rates.sum())
        occurred[code] = True
        extra[partners[code]] += boosts[code]
        tokens.append(V.DISEASE0 + code)
        ages.append(age)
    return np.asarray(tokens, np.int32), np.asarray(ages, np.float32)


def generate_dataset(cfg: SimulatorConfig = SimulatorConfig()
                     ) -> Tuple[List[Tuple[np.ndarray, np.ndarray]],
                                List[Tuple[np.ndarray, np.ndarray]]]:
    """Returns (train, val) lists of (tokens, ages) trajectories."""
    rng = np.random.default_rng(cfg.seed)
    a, b, partners, boosts = _hazard_params(rng, cfg)
    train = [simulate_patient(rng, a, b, partners, boosts, cfg)
             for _ in range(cfg.n_train)]
    val = [simulate_patient(rng, a, b, partners, boosts, cfg)
           for _ in range(cfg.n_val)]
    return train, val


_HAZARD_CACHE: dict = {}


def hazard_params(cfg: SimulatorConfig = SimulatorConfig()):
    """Disease universe (a, b, partners, boosts) for ``cfg``, cached.

    The hazard parameters are the FIRST draws from ``default_rng(cfg.seed)``
    in :func:`generate_dataset`, so this reproduces the sequential split's
    exact disease universe without simulating any patients.
    """
    key = dataclasses.astuple(cfg)
    if key not in _HAZARD_CACHE:
        _HAZARD_CACHE[key] = _hazard_params(np.random.default_rng(cfg.seed),
                                            cfg)
    return _HAZARD_CACHE[key]


def patient(index: int, cfg: SimulatorConfig = SimulatorConfig()
            ) -> Tuple[np.ndarray, np.ndarray]:
    """O(1) regeneration of cohort patient ``index``.

    Seeds an independent per-index stream ``default_rng([cfg.seed, index])``
    over the same hazard universe as :func:`generate_dataset`, so cohort
    workers and canary construction can materialize patient *i* without
    simulating ``0..i-1``.  This is a distinct (deterministic) patient
    family: it does NOT reproduce the sequential split's patient *i*, whose
    stream depends on every earlier patient's draws.  The sequential split
    itself is untouched and stays bit-stable.
    """
    a, b, partners, boosts = hazard_params(cfg)
    rng = np.random.default_rng([cfg.seed, int(index)])
    return simulate_patient(rng, a, b, partners, boosts, cfg)


def cohort(indices, cfg: SimulatorConfig = SimulatorConfig()
           ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Materialize ``patient(i, cfg)`` for each index (order-preserving)."""
    return [patient(i, cfg) for i in indices]
