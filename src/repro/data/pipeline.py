"""Batching pipeline: trajectory packing, target/dt construction, iterators.

Packing follows the Delphi training recipe: one patient per row, padded to
``seq_len``; targets are next events; ``target_dt`` is the (non-negative)
waiting time to the next event; the loss mask excludes positions whose target
is PAD or NO_EVENT (the "no event" marker is an input-side hazard refresh, not
a supervised outcome).
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np

from repro.data import vocab as V


def pack_trajectories(trajs: Sequence[Tuple[np.ndarray, np.ndarray]],
                      seq_len: int) -> Dict[str, np.ndarray]:
    """-> dict of arrays (N, seq_len): tokens, ages, targets, target_dt,
    loss_mask."""
    n = len(trajs)
    tokens = np.zeros((n, seq_len), np.int32)
    ages = np.zeros((n, seq_len), np.float32)
    targets = np.zeros((n, seq_len), np.int32)
    target_dt = np.zeros((n, seq_len), np.float32)
    mask = np.zeros((n, seq_len), np.float32)
    for i, (t, a) in enumerate(trajs):
        L = min(len(t), seq_len)
        tokens[i, :L] = t[:L]
        ages[i, :L] = a[:L]
        ages[i, L:] = a[L - 1] if L else 0.0
        if L > 1:
            targets[i, :L - 1] = t[1:L]
            target_dt[i, :L - 1] = np.maximum(a[1:L] - a[:L - 1], 1e-4)
            real = (t[1:L] != V.PAD) & (t[1:L] != V.NO_EVENT)
            mask[i, :L - 1] = real.astype(np.float32)
    return {"tokens": tokens, "ages": ages, "targets": targets,
            "target_dt": target_dt, "loss_mask": mask}


def batches(packed: Dict[str, np.ndarray], batch_size: int, *, seed: int = 0,
            epochs: int | None = None) -> Iterator[Dict[str, np.ndarray]]:
    """Shuffled epoch iterator over a packed dataset (drops the remainder)."""
    n = packed["tokens"].shape[0]
    rng = np.random.default_rng(seed)
    epoch = 0
    while epochs is None or epoch < epochs:
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i:i + batch_size]
            yield {k: v[idx] for k, v in packed.items()}
        epoch += 1


def lm_batch(rng: np.random.Generator, batch: int, seq_len: int,
             vocab_size: int) -> Dict[str, np.ndarray]:
    """Generic random-token LM batch (arch-zoo smoke tests and dry-runs)."""
    tokens = rng.integers(0, vocab_size, (batch, seq_len), dtype=np.int64)
    return {"tokens": tokens.astype(np.int32)}


def dataset_stats(trajs: Sequence[Tuple[np.ndarray, np.ndarray]]) -> Dict[str, float]:
    lens = np.array([len(t) for t, _ in trajs])
    death = np.array([V.DEATH in t for t, _ in trajs])
    last_age = np.array([a[-1] for _, a in trajs])
    n_dis = np.array([(t >= V.DISEASE0).sum() for t, _ in trajs])
    return {"n": float(len(trajs)), "mean_len": float(lens.mean()),
            "max_len": float(lens.max()), "death_frac": float(death.mean()),
            "mean_last_age": float(last_age.mean()),
            "mean_diseases": float(n_dis.mean())}
