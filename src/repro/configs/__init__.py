"""Architecture config registry.

``get_config(arch_id)`` returns the full production :class:`ModelConfig` for an
assigned architecture; ``get_config(arch_id, reduced=True)`` returns the
CPU-smoke-test variant of the same family (2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    AUDIO, DENSE, ENC_DEC, HYBRID, INPUT_SHAPES, MOE, SHAPES_BY_NAME, SSM, VLM,
    DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
    InputShape, ModelConfig, get_shape,
)

# arch-id -> module name in this package
_REGISTRY: Dict[str, str] = {
    "delphi-2m": "delphi_2m",
    "delphi-100m": "delphi_100m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "mamba2-780m": "mamba2_780m",
    "internvl2-26b": "internvl2_26b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "deepseek-7b": "deepseek_7b",
}

# The 10 architectures assigned to this paper (delphi-* are the paper's own).
ASSIGNED_ARCHS: List[str] = [
    "seamless-m4t-large-v2",
    "zamba2-1.2b",
    "qwen2.5-32b",
    "qwen2-moe-a2.7b",
    "mamba2-780m",
    "internvl2-26b",
    "tinyllama-1.1b",
    "h2o-danube-1.8b",
    "olmoe-1b-7b",
    "deepseek-7b",
]

ALL_ARCHS: List[str] = list(_REGISTRY)


def get_config(arch_id: str, *, reduced: bool = False) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch_id]}")
    cfg: ModelConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg
