"""TinyLlama-1.1B — llama2-architecture small dense model. [arXiv:2401.02385]"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    arch_type=DENSE,
    citation="arXiv:2401.02385",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    rope_theta=10_000.0,
    max_seq_len=32_768,
)
