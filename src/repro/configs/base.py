"""Model configuration dataclass covering every assigned architecture family.

A single ``ModelConfig`` describes dense / MoE / SSM / hybrid / enc-dec / VLM /
audio backbones.  Architecture configs live one-per-file in this package and
are looked up through :func:`repro.configs.get_config`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Architecture families ------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
ENC_DEC = "enc_dec"  # seq2seq (audio backbone)
VLM = "vlm"          # decoder-only with vision-patch frontend stub
AUDIO = "audio"      # enc-dec with audio-frame frontend stub


@dataclass(frozen=True)
class ModelConfig:
    # identity -----------------------------------------------------------
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | vlm | audio
    citation: str = ""

    # transformer core -----------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None     # default d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    activation: str = "swiglu"         # swiglu | gelu
    rope_theta: float = 10_000.0
    max_seq_len: int = 8192
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None   # SWA window (tokens); None = full attn

    # MoE ------------------------------------------------------------------
    n_experts: int = 0                 # routed experts (0 = dense MLP)
    top_k: int = 0
    n_shared_experts: int = 0          # always-on experts (qwen2-moe style)
    moe_d_ff: int = 0                  # per-expert hidden dim
    router_aux_coef: float = 0.01

    # SSM / Mamba2 (SSD) -----------------------------------------------------
    ssm_state: int = 0                 # N — state size per head
    ssm_expand: int = 2                # d_inner = expand * d_model
    ssm_head_dim: int = 64             # P — SSD head dim
    ssm_conv: int = 4                  # depthwise conv width
    ssm_chunk: int = 128               # SSD chunk length

    # hybrid (zamba2-style): shared attention block applied every k SSM layers
    attn_every: int = 0                # 0 = never (pure SSM)

    # encoder-decoder --------------------------------------------------------
    n_encoder_layers: int = 0          # >0 => enc-dec; decoder gets cross-attn
    enc_len_ratio: int = 8             # encoder frames = seq_len // ratio (audio)
    dec_enc_len: int = 4096            # encoder memory length for decode shapes

    # modality frontend stub (audio frames / vision patches) ------------------
    frontend: Optional[str] = None     # None | "audio_frames" | "vision_patches"
    n_frontend_tokens: int = 256       # VLM: patch tokens prepended to text

    # Delphi (the paper's technique, T1) --------------------------------------
    dual_head: bool = False            # event+time competing-exponential head
    age_encoding: bool = False         # continuous age encoding (replaces pos enc)
    death_token: int = 1               # termination token id ("Death")
    max_age: float = 85.0              # years (paper default)
    no_event_token: int = 2            # padding/"no event" token (loss-masked)

    # numerics / runtime -------------------------------------------------------
    dtype: str = "bfloat16"            # activation dtype on the TPU path
    param_dtype: str = "float32"
    use_pallas: bool = False           # kernels validated separately; jnp path default
    remat: bool = False                # activation checkpointing over layer scan
    # cost-accounting mode (dry-run FLOPs compile): XLA's CPU cost analysis
    # counts while-loop bodies ONCE, so the dry-run re-lowers with unrolled
    # python-loop layer stacks + direct (loop-free) attention to obtain exact
    # HLO FLOP counts.  Never used for the deployment graph.
    unroll_layers: bool = False
    attn_direct: bool = False
    # §Perf variant: shard attention score/context compute over the sequence
    # dim on the "model" axis (context parallelism).  Fixes replicated
    # attention compute when head counts don't divide the model axis
    # (e.g. qwen2.5's 40 q / 8 kv heads on a 16-way axis).
    seq_shard_attn: bool = False

    # ------------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        assert self.arch_type in (DENSE, MOE, SSM, HYBRID, ENC_DEC, VLM, AUDIO), self.arch_type
        if self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA requires n_heads % n_kv_heads == 0"
        if self.n_experts:
            assert 0 < self.top_k <= self.n_experts

    # convenience -------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == SSM

    @property
    def has_encoder(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_per_kv(self) -> int:
        if self.n_heads == 0:
            return 1
        return self.n_heads // max(self.n_kv_heads, 1)

    def with_sliding_window(self, window: int) -> "ModelConfig":
        """Sub-quadratic long-context variant (DESIGN.md long_500k policy)."""
        return dataclasses.replace(self, sliding_window=window)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family, 2 layers, d_model<=512, <=4 experts."""
        kw = dict(
            n_layers=2,
            d_model=256,
            head_dim=64,
            d_ff=512,
            vocab_size=min(self.vocab_size, 512),
            max_seq_len=256,
        )
        if self.n_heads:
            # 4 query heads, preserving the GQA ratio where possible
            kw["n_heads"] = 4
            kw["n_kv_heads"] = max(1, 4 // min(self.q_per_kv, 4))
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(self.top_k, 2), moe_d_ff=128,
                      n_shared_experts=min(self.n_shared_experts, 1))
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
        if self.attn_every:
            kw.update(attn_every=1, n_layers=2)
        if self.n_encoder_layers:
            kw.update(n_encoder_layers=2, dec_enc_len=64)
        if self.sliding_window:
            kw.update(sliding_window=64)
        if self.frontend:
            kw.update(n_frontend_tokens=8)
        return self.replace(**kw)


@dataclass(frozen=True)
class InputShape:
    """A named (seq_len, global_batch, mode) workload."""
    name: str
    seq_len: int
    global_batch: int
    mode: str            # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


def get_shape(name: str) -> InputShape:
    return SHAPES_BY_NAME[name]
