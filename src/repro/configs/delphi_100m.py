"""Delphi-100M — scaled Delphi variant for the end-to-end training driver.

Same technique (age encoding + dual head), ~100M backbone parameters; used by
``examples``/``launch/train.py`` when a larger-than-paper model is wanted.
"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="delphi-100m",
    arch_type=DENSE,
    citation="this work (scaled variant of Delphi-2M)",
    n_layers=16,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=1289,
    norm="layernorm",
    activation="gelu",
    max_seq_len=1024,
    tie_embeddings=True,
    dual_head=True,
    age_encoding=True,
)
