"""Qwen2-MoE-A2.7B — 60 routed experts top-4 plus 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.base import MOE, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type=MOE,
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,            # per-expert hidden size (spec)
    moe_d_ff=1408,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,   # 4 shared experts, each moe_d_ff wide (5632 combined)
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
)
