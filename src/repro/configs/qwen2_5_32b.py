"""Qwen2.5-32B — dense GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B family card]"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    arch_type=DENSE,
    citation="hf:Qwen/Qwen2.5-0.5B (family card, 32B variant)",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
)
