"""OLMoE-1B-7B — 64 routed experts, top-8, no shared experts. [arXiv:2409.02060]"""
from repro.configs.base import MOE, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type=MOE,
    citation="arXiv:2409.02060",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    moe_d_ff=1024,
    n_experts=64,
    top_k=8,
    n_shared_experts=0,
    vocab_size=50304,
    rope_theta=10_000.0,
    max_seq_len=32_768,
)
