"""Delphi-2M — the paper's model (nanoGPT-style GPT over ICD-10 event tokens).

Faithful to the reference report: ~2M parameters, continuous age encoding in
place of positional encodings, dual event/time head trained with the
cross-entropy + exponential waiting-time loss, "Death" termination token and
max-age 85 defaults.  [Shmatko et al., Nature 2025; gerstung-lab/Delphi;
Duarte et al. 2026 (this paper)]
"""
from repro.configs.base import DENSE, ModelConfig

# Vocabulary: 1,270 ICD-10-level disease tokens + sex/lifestyle + specials
# (pad=0, Death=1, no-event=2), rounded to 1,289 as in our synthetic vocab.
CONFIG = ModelConfig(
    name="delphi-2m",
    arch_type=DENSE,
    citation="arXiv/Nature 2025 Delphi-2M; Duarte et al. 2026 (paper reproduced here)",
    n_layers=12,
    d_model=120,
    n_heads=12,
    n_kv_heads=12,
    d_ff=480,
    vocab_size=1289,
    norm="layernorm",
    activation="gelu",
    max_seq_len=256,
    tie_embeddings=True,
    dual_head=True,
    age_encoding=True,
    death_token=1,
    no_event_token=2,
    max_age=85.0,
)
