"""Mamba2-780M — attention-free SSD (state-space duality). [arXiv:2405.21060]

d_inner = 2*1536 = 3072, head_dim 64 -> 48 SSD heads, state N=128.
"""
from repro.configs.base import SSM, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_type=SSM,
    citation="arXiv:2405.21060",
    n_layers=48,
    d_model=1536,
    n_heads=0,            # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=128,
    max_seq_len=1_048_576,  # constant-state decode: unbounded context
)
