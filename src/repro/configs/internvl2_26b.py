"""InternVL2-26B — InternLM2-20B language backbone + InternViT frontend stub.
[arXiv:2404.16821]

Per the mandate the ViT + projector are a stub: ``input_specs`` supplies 256
precomputed patch embeddings of shape (batch, 256, d_model) which the decoder
consumes prepended to the text sequence.
"""
from repro.configs.base import VLM, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    arch_type=VLM,
    citation="arXiv:2404.16821",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
    frontend="vision_patches",
    n_frontend_tokens=256,
)
