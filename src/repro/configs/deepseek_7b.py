"""DeepSeek-7B — llama-architecture dense model. [arXiv:2401.02954]"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    arch_type=DENSE,
    citation="arXiv:2401.02954",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    rope_theta=10_000.0,
    max_seq_len=32_768,
)
