"""SeamlessM4T-large-v2 — encoder-decoder multimodal (speech) backbone.
[arXiv:2308.11596]

Per the mandate the mel-spectrogram + conv codec is a stub: ``input_specs``
supplies precomputed frame embeddings (batch, seq//8, d_model) to the 24-layer
encoder; the 24-layer causal decoder (with cross-attention) is fully
implemented and is what decode shapes lower.
"""
from repro.configs.base import AUDIO, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type=AUDIO,
    citation="arXiv:2308.11596",
    n_layers=24,           # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    norm="layernorm",
    activation="gelu",
    max_seq_len=32_768,
    frontend="audio_frames",
    enc_len_ratio=8,
    dec_enc_len=4096,
)
