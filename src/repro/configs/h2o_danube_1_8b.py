"""H2O-Danube-1.8B — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""
from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    arch_type=DENSE,
    citation="arXiv:2401.16818",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=10_000.0,
    max_seq_len=32_768,
)
