"""Zamba2-1.2B — Mamba2 backbone with a weight-shared attention block applied
periodically. [arXiv:2411.15242]

38 Mamba2 layers; one shared transformer block (attn + MLP, d_ff 8192) applied
before every 6th Mamba layer (7 applications).  The original interleaves two
shared blocks with LoRA-specialized projections; we share a single block and
note the simplification in DESIGN.md.
"""
from repro.configs.base import HYBRID, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type=HYBRID,
    citation="arXiv:2411.15242",
    n_layers=38,          # Mamba2 layers
    d_model=2048,
    n_heads=32,           # shared attention block (MHA: kv = heads)
    n_kv_heads=32,
    d_ff=8192,            # shared block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=128,
    attn_every=6,
    sliding_window=4096,  # shared attn block windows at long context
    max_seq_len=1_048_576,
)
